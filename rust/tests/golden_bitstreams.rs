//! Golden bit-stream vectors: the wire format is pinned byte-for-byte so
//! codec refactors cannot silently change it. Fixtures live in
//! `tests/golden/` (raw little-endian f32 input, expected encoded bytes)
//! and were produced by `tests/golden/gen_golden.py`, a line-by-line port
//! of this codec with its own self-checks.
//!
//! Three vectors cover the three encoder paths: the generic truncated-unary
//! path (uniform N=4), the specialized 1-bit path (uniform N=2), and the
//! entropy-constrained path with an in-band reconstruction table (ECQ N=4).

use lwfc::codec::{
    decode, decode_indices, Encoder, EncoderConfig, NonUniformQuantizer, QuantKind, Quantizer,
    UniformQuantizer,
};

fn f32_le(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0);
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Assert: encoding `input` with `quantizer` reproduces `expected` exactly,
/// and decoding `expected` reproduces element-wise fake-quant of `input`.
fn check_golden(name: &str, input: &[u8], expected: &[u8], quantizer: Quantizer) {
    let xs = f32_le(input);
    let q = quantizer.clone();

    let mut enc = Encoder::new(EncoderConfig::classification(quantizer, 32));
    let stream = enc.encode(&xs);
    assert_eq!(
        stream.bytes, expected,
        "{name}: encoded bytes diverge from the golden vector — the wire \
         format changed. If intentional, regenerate tests/golden/ via \
         gen_golden.py and bump the container/codec version."
    );

    let (decoded, header) = decode(expected, xs.len()).unwrap();
    assert_eq!(decoded.len(), xs.len(), "{name}: decoded length");
    assert_eq!(header.levels, q.levels(), "{name}: header levels");
    for (i, (&x, &y)) in xs.iter().zip(&decoded).enumerate() {
        assert_eq!(y, q.fake_quant(x), "{name}: element {i}");
    }
}

#[test]
fn golden_uniform_n4() {
    check_golden(
        "uniform_n4",
        include_bytes!("golden/uniform_n4.f32"),
        include_bytes!("golden/uniform_n4.lwfc"),
        Quantizer::Uniform(UniformQuantizer::new(0.0, 6.0, 4)),
    );
}

#[test]
fn golden_uniform_n2_specialized_one_bit_path() {
    check_golden(
        "uniform_n2",
        include_bytes!("golden/uniform_n2.f32"),
        include_bytes!("golden/uniform_n2.lwfc"),
        Quantizer::Uniform(UniformQuantizer::new(0.0, 6.0, 2)),
    );
}

#[test]
fn golden_ecq_n4() {
    // Hand-pinned Algorithm-1-style design (x̂_0 = c_min, x̂_{N-1} = c_max);
    // must match gen_golden.py exactly.
    let q = NonUniformQuantizer {
        recon: vec![0.0, 1.0, 2.5, 6.0],
        thresholds: vec![0.5, 1.75, 4.25],
        c_min: 0.0,
        c_max: 6.0,
    };
    check_golden(
        "ecq_n4",
        include_bytes!("golden/ecq_n4.f32"),
        include_bytes!("golden/ecq_n4.lwfc"),
        Quantizer::NonUniform(q),
    );
}

#[test]
fn golden_ecq_header_carries_recon_table() {
    let expected = include_bytes!("golden/ecq_n4.lwfc");
    let n = include_bytes!("golden/ecq_n4.f32").len() / 4;
    let (_, header) = decode_indices(expected, n).unwrap();
    assert_eq!(header.quant, QuantKind::EntropyConstrained);
    assert_eq!(header.recon.as_deref(), Some(&[0.0f32, 1.0, 2.5, 6.0][..]));
    assert_eq!(header.c_min, 0.0);
    assert_eq!(header.c_max, 6.0);
}

#[test]
fn golden_vectors_exercise_every_level() {
    // A golden vector that misses a level would under-pin the format.
    let n = include_bytes!("golden/uniform_n4.f32").len() / 4;
    let (idx, _) = decode_indices(include_bytes!("golden/uniform_n4.lwfc"), n).unwrap();
    let mut seen = [false; 4];
    for &i in &idx {
        seen[i as usize] = true;
    }
    assert!(seen.iter().all(|&s| s), "levels missing from uniform_n4: {seen:?}");
}

#[test]
fn golden_streams_reject_truncation() {
    let bytes = include_bytes!("golden/uniform_n4.lwfc");
    assert!(decode(&bytes[..8], 512).is_err(), "truncated header accepted");
}

#!/usr/bin/env python3
"""Generate the golden bit-stream fixtures for tests/golden_bitstreams.rs.

This is a line-by-line port of the Rust encoder pipeline
(rust/src/codec/{cabac,entropy,binarize,uniform,ecq,header}.rs): clip ->
N-level quantization -> truncated-unary binarization -> entropy stage ->
12-byte classification header. Every entropy backend is ported: the
LZMA-style binary range coder with 11-bit adaptive contexts (CABAC), and
the interleaved rANS coder with static 12-bit per-bit-position frequency
tables signaled in-band, at both its wire interleave widths (header byte
0 bits 6-7 carry the backend id: 0 = CABAC, 1 = 2-way rANS, 3 = 4-way
rANS; id 2 is unassigned).

The rANS fixtures reuse the CABAC fixtures' .f32 inputs (same tensors,
three backends), so each rans_*.lwfc / rans4_*.lwfc is directly
differential against its legacy counterpart.

All arithmetic is integer (CABAC) or exactly-emulated IEEE f32
(quantizer): a product/sum of two f32 values is exact in f64, so rounding
the f64 result back to f32 reproduces Rust's f32 op bit-for-bit. Input
values are additionally kept >= 1e-3 away from every quantizer decision
boundary so no representation subtlety can flip an index.

Run from this directory:  python3 gen_golden.py
"""

import struct

PROB_BITS = 11
PROB_ONE = 1 << PROB_BITS  # 2048
PROB_INIT = PROB_ONE // 2  # 1024
ADAPT_SHIFT = 5
TOP = 1 << 24
MASK32 = 0xFFFFFFFF

# --------------------------------------------------------------------------
# Wire/container constants mirrored from rust/src/consts.rs. This block is
# parsed *textually* by `cargo xtask analyze` (the cross-artifact invariant
# diff) and by rust/tests/consts_parity.rs, so keep each entry a plain
# `NAME = literal` line. If a value here drifts from the Rust side, both
# checkers fail the build.
# --------------------------------------------------------------------------

BATCH_MAGIC = b"LWFB"
BATCH_MIN_VERSION = 1
BATCH_VERSION_PLAIN = 2
BATCH_VERSION = 3
BATCH_VERSION_TEMPORAL = 4

ENTROPY_ID_CABAC = 0
ENTROPY_ID_RANS = 1
ENTROPY_ID_RANS4 = 3

NET_MAGIC = b"LWFN"
NET_VERSION = 4
NET_MIN_VERSION = 1

FRAME_KIND_ITEM = 0
FRAME_KIND_OUTCOME = 1
FRAME_KIND_BUSY = 2
FRAME_KIND_RESET = 3


def f32(x):
    """Round a Python float to the nearest IEEE-754 binary32 value."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


class Context:
    __slots__ = ("p0",)

    def __init__(self):
        self.p0 = PROB_INIT

    def update(self, bit):
        if bit:
            self.p0 -= self.p0 >> ADAPT_SHIFT
        else:
            self.p0 += (PROB_ONE - self.p0) >> ADAPT_SHIFT


class CabacEncoder:
    def __init__(self):
        self.low = 0
        self.range = MASK32
        self.cache = 0
        self.cache_size = 1
        self.out = bytearray()

    def shift_low(self):
        if (self.low & MASK32) < 0xFF000000 or (self.low >> 32) != 0:
            carry = (self.low >> 32) & 0xFF
            temp = self.cache
            while True:
                self.out.append((temp + carry) & 0xFF)
                temp = 0xFF
                self.cache_size -= 1
                if self.cache_size == 0:
                    break
            self.cache = (self.low >> 24) & 0xFF
        self.cache_size += 1
        self.low = ((self.low & MASK32) << 8) & MASK32

    def encode(self, ctx, bit):
        bound = (self.range >> PROB_BITS) * ctx.p0  # always < 2^32
        if not bit:
            self.range = bound
        else:
            self.low += bound
            self.range -= bound
        ctx.update(bit)
        while self.range < TOP:
            self.range = (self.range << 8) & MASK32
            self.shift_low()

    def finish(self):
        for _ in range(5):
            self.shift_low()
        return bytes(self.out)


class CabacDecoder:
    def __init__(self, data):
        self.data = data
        self.pos = 1  # first byte is the encoder's initial cache (0)
        self.code = 0
        self.range = MASK32
        for _ in range(4):
            self.code = ((self.code << 8) | self.next_byte()) & MASK32

    def next_byte(self):
        b = self.data[self.pos] if self.pos < len(self.data) else 0
        self.pos += 1
        return b

    def decode(self, ctx):
        bound = (self.range >> PROB_BITS) * ctx.p0
        if self.code < bound:
            self.range = bound
            bit = False
        else:
            self.code -= bound
            self.range -= bound
            bit = True
        ctx.update(bit)
        while self.range < TOP:
            self.range = (self.range << 8) & MASK32
            self.code = ((self.code << 8) | self.next_byte()) & MASK32
        return bit


# --------------------------------------------------------------------------
# Interleaved rANS (port of rust/src/codec/entropy.rs RansBackend).
# --------------------------------------------------------------------------

RANS_SCALE_BITS = 12
RANS_SCALE = 1 << RANS_SCALE_BITS  # 4096
RANS_LOWER = 1 << 23


def rans_freq_table(hist, levels):
    """Per-position P(bit=0) scaled to [1, 4095], exactly as the Rust
    freq_table: position pos sees a one for every index > pos and a zero
    for every index == pos."""
    nctx = max(levels - 1, 1)
    ones = 0
    rev = []
    for pos in range(nctx - 1, -1, -1):
        ones += hist[pos + 1]
        zeros = hist[pos]
        total = zeros + ones
        if total == 0:
            p = RANS_SCALE // 2
        else:
            p = (zeros * RANS_SCALE + total // 2) // total
        rev.append(min(max(p, 1), RANS_SCALE - 1))
    return list(reversed(rev))


def rans_start_freq(p0, bit):
    return (p0, RANS_SCALE - p0) if bit else (0, p0)


def rans_encode_bit(state, buf, p0, bit):
    start, freq = rans_start_freq(p0, bit)
    x_max = ((RANS_LOWER >> RANS_SCALE_BITS) << 8) * freq
    x = state
    while x >= x_max:
        buf.append(x & 0xFF)
        x >>= 8
    return ((x // freq) << RANS_SCALE_BITS) + (x % freq) + start


def rans_encode_payload(indices, levels, ways=2):
    """Static tables (u16 LE each) + `ways` initial u32 LE states + the
    interleaved byte stream. Bit i of the forward TU bit sequence uses
    state i & (ways - 1); encoding runs the decoder program in exact
    reverse. ways=2 is backend id 1 (RansBackend), ways=4 id 3
    (RansBackend4)."""
    nctx = max(levels - 1, 1)
    hist = [0] * levels
    for n in indices:
        hist[n] += 1
    p0 = rans_freq_table(hist, levels)
    out = bytearray()
    for p in p0:
        out += struct.pack("<H", p)
    total_bits = sum(hist[pos] + sum(hist[pos + 1:]) for pos in range(nctx))
    buf = bytearray()
    states = [RANS_LOWER] * ways
    bi = total_bits
    for n in reversed(indices):
        if n + 1 != levels:
            bi -= 1
            k = bi & (ways - 1)
            states[k] = rans_encode_bit(states[k], buf, p0[n], False)
        for pos in range(n - 1, -1, -1):
            bi -= 1
            k = bi & (ways - 1)
            states[k] = rans_encode_bit(states[k], buf, p0[pos], True)
    assert bi == 0, "bit accounting mismatch"
    # Highest-numbered state first, so after the reversal the payload
    # starts with state0..state{ways-1}, each little-endian.
    for s in reversed(states):
        buf += s.to_bytes(4, "big")
    buf.reverse()
    out += buf
    return bytes(out)


class RansError(Exception):
    pass


def rans_decode_payload(payload, levels, elements, ways=2):
    """Mirror of RansBackendN::decode_payload, including every error path
    (truncation, bad tables, final-state and full-consumption checks)."""
    nctx = max(levels - 1, 1)
    table_len = nctx * 2
    header_len = table_len + 4 * ways
    if len(payload) < header_len:
        raise RansError("payload truncated: header")
    p0 = []
    for t in range(nctx):
        (v,) = struct.unpack_from("<H", payload, 2 * t)
        if v == 0 or v >= RANS_SCALE:
            raise RansError(f"frequency {v} out of range")
        p0.append(v)
    states = [
        struct.unpack_from("<I", payload, table_len + 4 * w)[0]
        for w in range(ways)
    ]
    if any(s < RANS_LOWER for s in states):
        raise RansError("initial state below bound")
    pos = header_len
    bi = 0
    out = []
    for _ in range(elements):
        n = 0
        while n + 1 < levels:
            k = bi & (ways - 1)
            bi += 1
            p = p0[n]
            s = states[k] & (RANS_SCALE - 1)
            bit = s >= p
            start, freq = rans_start_freq(p, bit)
            states[k] = freq * (states[k] >> RANS_SCALE_BITS) + s - start
            while states[k] < RANS_LOWER:
                if pos >= len(payload):
                    raise RansError("payload truncated mid-stream")
                states[k] = (states[k] << 8) | payload[pos]
                pos += 1
            if not bit:
                break
            n += 1
        out.append(n)
    if states != [RANS_LOWER] * ways:
        raise RansError("final-state check failed")
    if pos != len(payload):
        raise RansError("unconsumed trailing bytes")
    return out


def num_contexts(levels):
    return max(levels - 1, 1)


def encode_tu(n, levels, emit):
    for pos in range(n):
        emit(pos, True)
    if n + 1 != levels:
        emit(n, False)


def decode_tu(levels, next_bit):
    n = 0
    while n + 1 < levels:
        if next_bit(n):
            n += 1
        else:
            break
    return n


def clip(x, c_min, c_max):
    if x >= c_max:
        return c_max
    if x <= c_min:
        return c_min
    return x  # NaN never appears in the fixtures


def uniform_index(x, c_min, c_max, levels):
    """Rust UniformQuantizer::index with exact f32 emulation."""
    scale = f32((levels - 1) / (c_max - c_min))
    xc = clip(f32(x), f32(c_min), f32(c_max))
    v = f32(f32((xc - f32(c_min)) * scale) + 0.5)
    return int(v)  # truncation; argument is >= 0


def uniform_reconstruct(n, c_min, c_max, levels):
    """Rust UniformQuantizer::reconstruct with exact f32 emulation."""
    if n + 1 == levels:
        return f32(c_max)  # exact, like the Rust top-bin special case
    scale = f32((levels - 1) / (c_max - c_min))
    inv_scale = f32(1.0 / scale)
    return f32(f32(c_min) + f32(f32(n) * inv_scale))


def zigzag(d):
    """i32 zigzag map (Rust: ((d << 1) ^ (d >> 31)) as u16)."""
    return ((d << 1) ^ (d >> 31)) & 0xFFFF


def unzigzag(z):
    return (z >> 1) ^ -(z & 1)


def ecq_index(x, recon, thresholds, c_min, c_max):
    xc = clip(f32(x), f32(c_min), f32(c_max))
    n = 0
    for t in thresholds:
        if xc >= f32(t):
            n += 1
        else:
            break
    return n


def header_bytes(quant_kind, levels, c_min, c_max, img, recon=None, backend=0):
    out = bytearray()
    # classification | quant bits 4-5 | entropy backend bits 6-7
    out.append(0x00 | (quant_kind << 4) | (backend << 6))
    out.append(levels)
    out += struct.pack("<f", c_min)
    out += struct.pack("<f", c_max)
    out.append(img)
    out.append(img)
    if quant_kind == 1:
        assert recon is not None and len(recon) == levels
        for r in recon:
            out += struct.pack("<f", r)
    return bytes(out)


def encode_stream(indices, levels, head):
    ctxs = [Context() for _ in range(num_contexts(levels))]
    enc = CabacEncoder()
    for n in indices:
        encode_tu(n, levels, lambda pos, bit: enc.encode(ctxs[pos], bit))
    return head + enc.finish()


def decode_stream_indices(payload, levels, count):
    """Decode CABAC payload (header already stripped) back to indices."""
    ctxs = [Context() for _ in range(num_contexts(levels))]
    dec = CabacDecoder(payload)
    return [decode_tu(levels, lambda pos: dec.decode(ctxs[pos])) for _ in range(count)]


# --------------------------------------------------------------------------
# Port self-checks (mirror rust/src/codec/cabac.rs unit-test pins).
# --------------------------------------------------------------------------

def self_check():
    # Hand-derived micro-vector: one `false` bit with a fresh context.
    # bound = (0xFFFFFFFF >> 11) * 1024 = 0x7FFFFC00; range stays >= TOP,
    # finish emits the zero cache then four zero low bytes.
    e = CabacEncoder()
    e.encode(Context(), False)
    assert e.finish() == b"\x00\x00\x00\x00\x00", "micro-vector false"

    # Encode/decode roundtrip, multi-context, mixed skew.
    import random

    rng = random.Random(1234)
    bits = [rng.random() < 0.2 for _ in range(20000)]
    ctxs = [Context() for _ in range(3)]
    enc = CabacEncoder()
    for i, b in enumerate(bits):
        enc.encode(ctxs[i % 3], b)
    data = enc.finish()
    dctxs = [Context() for _ in range(3)]
    dec = CabacDecoder(data)
    for i, b in enumerate(bits):
        assert dec.decode(dctxs[i % 3]) == b, f"roundtrip bit {i}"

    # Constant stream nearly free (Rust test: 100k zeros < 350 bytes).
    ctx = Context()
    enc = CabacEncoder()
    for _ in range(100000):
        enc.encode(ctx, False)
    n = len(enc.finish())
    assert n < 350, f"constant stream took {n} bytes"

    # Skewed stream compresses (Rust test: p=1/16 under 0.40 bits/bit).
    rng = random.Random(8)
    ctx = Context()
    enc = CabacEncoder()
    nbits = 64000
    for _ in range(nbits):
        enc.encode(ctx, rng.randrange(16) == 0)
    bpb = len(enc.finish()) * 8.0 / nbits
    assert bpb < 0.40, f"bits/bit {bpb}"

    # TU matches the paper's 4-level example: 0,10,110,111.
    for n, want in [(0, [False]), (1, [True, False]), (2, [True, True, False]), (3, [True, True, True])]:
        got = []
        encode_tu(n, 4, lambda _p, b: got.append(b))
        assert got == want, f"TU {n}"

    # ---- rANS self-checks (the Rust backend is a transliteration of the
    # functions above, so these runs executably validate its algorithm) ----
    import random

    for ways in (2, 4):
        for seed, levels, n in [
            (1, 2, 0), (2, 2, 1), (3, 2, 5000), (4, 3, 777), (5, 4, 20000),
            (6, 8, 10000), (7, 5, 1), (8, 16, 3000), (9, 4, 2),
        ]:
            rng = random.Random(seed)
            # Skewed toward low indices, like clipped activations.
            idx = [min(int(rng.expovariate(1.2)), levels - 1) for _ in range(n)]
            payload = rans_encode_payload(idx, levels, ways)
            assert rans_decode_payload(payload, levels, n, ways) == idx, \
                f"rANS roundtrip failed (ways={ways} seed={seed} levels={levels} n={n})"
            # Truncation at every prefix must error, never mis-decode.
            for cut in range(len(payload)):
                try:
                    got = rans_decode_payload(payload[:cut], levels, n, ways)
                except RansError:
                    continue
                assert False, \
                    f"truncation to {cut} decoded {len(got)} symbols (ways={ways})"
            # Element overcount / undercount must error via the final-state
            # or consumption checks.
            for bad_n in [n + 1, n + 97]:
                try:
                    rans_decode_payload(payload, levels, bad_n, ways)
                    assert False, f"overcount {bad_n} accepted (ways={ways})"
                except RansError:
                    pass
            if n > 0:
                try:
                    rans_decode_payload(payload, levels, n - 1, ways)
                    assert False, f"undercount accepted (ways={ways})"
                except RansError:
                    pass

        # Degenerate single-bin streams exercise the [1, 4095] clamps.
        for idx in ([0] * 4096, [1] * 4096, [3] * 4096):
            payload = rans_encode_payload(idx, 4, ways)
            assert rans_decode_payload(payload, 4, len(idx), ways) == idx

        # Static tables must still compress skewed data well below raw cost.
        rng = random.Random(99)
        idx = [min(int(rng.expovariate(2.0)), 3) for _ in range(65536)]
        payload = rans_encode_payload(idx, 4, ways)
        bpe = len(payload) * 8.0 / len(idx)
        assert bpe < 1.6, f"rANS bits/element {bpe} (ways={ways})"

    # The interleave widths share frequency tables (same histogram math)
    # and differ only past the table: 8 extra side-info bytes for ways=4.
    rng = random.Random(123)
    idx = [min(int(rng.expovariate(1.5)), 7) for _ in range(10000)]
    p2 = rans_encode_payload(idx, 8, 2)
    p4 = rans_encode_payload(idx, 8, 4)
    assert p2[:14] == p4[:14], "tables diverged between interleave widths"
    assert rans_decode_payload(p2, 8, len(idx), 2) == \
        rans_decode_payload(p4, 8, len(idx), 4)
    # Reading a 4-way payload as 2-way (or vice versa) must error, not
    # silently mis-decode: the interleave is part of the format.
    mismatch_caught = False
    for payload, ways in ((p4, 2), (p2, 4)):
        try:
            got = rans_decode_payload(payload, 8, len(idx), ways)
            mismatch_caught = mismatch_caught or got != idx
        except RansError:
            mismatch_caught = True
    assert mismatch_caught, "interleave mismatch went undetected both ways"

    print("self-checks passed")


# --------------------------------------------------------------------------
# Batched container (port of rust/src/codec/{header,batch}.rs).
# --------------------------------------------------------------------------

def fnv1a(data):
    h = 0x811C9DC5
    for b in data:
        h ^= b
        h = (h * 0x01000193) & 0xFFFFFFFF
    return h


def spec_record_uniform(cmin, cmax, levels):
    return bytes([0, levels]) + struct.pack("<f", cmin) + struct.pack("<f", cmax)


def spec_record_ecq(cmin, cmax, recon, thresholds):
    out = bytearray([1, len(recon)])
    out += struct.pack("<f", cmin)
    out += struct.pack("<f", cmax)
    for r in recon:
        out += struct.pack("<f", r)
    for t in thresholds:
        out += struct.pack("<f", t)
    return bytes(out)


def container_bytes(tiles, entropy_id=0, specs=None, temporal=None):
    """tiles: [(elements, payload_bytes)]; specs: v3 per-tile spec records
    (None = v2, byte-identical to the pre-v3 writer); temporal: v4
    per-tile (mode, generation) records — their presence alone selects
    version 4 (flags byte + 5-byte records between the directory entries
    and the spec block), exactly like the Rust writer."""
    out = bytearray(BATCH_MAGIC)
    if temporal is not None:
        out.append(BATCH_VERSION_TEMPORAL)
    else:
        out.append(BATCH_VERSION if specs is not None else BATCH_VERSION_PLAIN)
    out.append(entropy_id)
    out += struct.pack("<I", len(tiles))
    out += struct.pack("<Q", sum(e for e, _ in tiles))
    for e, p in tiles:
        out += struct.pack("<I", e)
        out += struct.pack("<I", len(p))
        out += struct.pack("<I", fnv1a(p))
    if temporal is not None:
        out.append(1 if specs is not None else 0)  # flags: bit 0 = specs
        for mode, gen in temporal:
            out.append(mode)
            out += struct.pack("<I", gen)
    if specs is not None:
        for srec in specs:
            out += srec
    for _, p in tiles:
        out += p
    return bytes(out)


def container_v4_self_check(blob, plan, refs, c_min, c_max, levels, head_len):
    """Re-parse a v4 container and run the decode-session semantics: parse
    the temporal block, decode every tile (intra under `levels`, inter as
    an unzigzagged residual under 2N-1 added to the reference's
    re-quantized indices), and compare against the expected indices.

    plan: [(mode, generation, expected_indices)]; refs: the reference
    store — per tile, the previous frame's reconstructed f32 values (None
    for frame 0). Returns the reconstructions, i.e. the next frame's
    reference store."""
    assert blob[:4] == BATCH_MAGIC and blob[4] == BATCH_VERSION_TEMPORAL
    count = struct.unpack_from("<I", blob, 6)[0]
    total = struct.unpack_from("<Q", blob, 10)[0]
    assert count == len(plan)
    entries = []
    off = 18
    for _ in range(count):
        e, bl, ck = struct.unpack_from("<III", blob, off)
        entries.append((e, bl, ck))
        off += 12
    assert total == sum(e for e, _, _ in entries)
    assert blob[off] == 0, "fixture carries no spec block"
    off += 1
    records = []
    for _ in range(count):
        mode = blob[off]
        gen = struct.unpack_from("<I", blob, off + 1)[0]
        assert mode in (0, 1) and gen != 0
        records.append((mode, gen))
        off += 5
    recons = []
    for (e, bl, ck), (mode, gen, idx), ref, rec in zip(entries, plan, refs, records):
        payload = blob[off:off + bl]
        off += bl
        assert e == len(idx) and ck == fnv1a(payload)
        assert rec == (mode, gen), f"temporal record {rec} != planned {(mode, gen)}"
        if mode == 0:
            got = decode_stream_indices(payload[head_len:], levels, e)
        else:
            assert ref is not None and len(ref) == e
            z = decode_stream_indices(payload[head_len:], 2 * levels - 1, e)
            got = []
            for j, r in enumerate(ref):
                n = uniform_index(r, c_min, c_max, levels) + unzigzag(z[j])
                assert 0 <= n < levels, "inter residual leaves the level range"
                got.append(n)
        assert got == idx, f"v4 tile mis-decodes (mode {mode})"
        recons.append([uniform_reconstruct(n, c_min, c_max, levels) for n in got])
    assert off == len(blob)
    return recons


def container_self_check(blob, tile_plan):
    """Re-parse a generated container and decode every tile back to the
    expected indices. tile_plan: [(indices, levels, head_len)]."""
    assert blob[:4] == BATCH_MAGIC
    version = blob[4]
    count = struct.unpack_from("<I", blob, 6)[0]
    total = struct.unpack_from("<Q", blob, 10)[0]
    assert count == len(tile_plan)
    entries = []
    off = 18
    for _ in range(count):
        e, bl, ck = struct.unpack_from("<III", blob, off)
        entries.append((e, bl, ck))
        off += 12
    if version >= 3:
        for _ in range(count):  # skip self-delimiting spec records
            kind, levels = blob[off], blob[off + 1]
            off += 10 + (levels * 4 + (levels - 1) * 4 if kind == 1 else 0)
    assert total == sum(e for e, _, _ in entries)
    for (e, bl, ck), (idx, levels, head_len) in zip(entries, tile_plan):
        payload = blob[off:off + bl]
        off += bl
        assert e == len(idx) and ck == fnv1a(payload)
        got = decode_stream_indices(payload[head_len:], levels, len(idx))
        assert got == idx, "container tile mis-decodes"
    assert off == len(blob)


# --------------------------------------------------------------------------
# Fixture generation.
# --------------------------------------------------------------------------

def gen_inputs(seed, n, boundaries, lo, hi, margin=1e-3):
    """Deterministic activation-like f32 values, all >= margin away from
    every quantizer decision boundary (after f32 rounding)."""
    import random

    rng = random.Random(seed)
    out = []
    while len(out) < n:
        u = rng.random()
        if u < 0.15:
            x = -rng.random() * 1.5  # below range -> clips to c_min
        elif u < 0.25:
            x = hi + rng.random() * 3.0  # above range -> clips to c_max
        else:
            x = rng.random() * (hi - lo) + lo
        xf = f32(x)
        if all(abs(xf - b) > margin for b in boundaries):
            out.append(xf)
    return out


# Generated fixture bytes, keyed by filename. In write mode they are
# saved to disk; in --check mode they are byte-compared against the
# committed files (CI runs this so the fixtures stay executably verified).
OUTPUTS = {}


def emit(name, blob):
    OUTPUTS[name] = bytes(blob)


def write_fixture(stem, values, stream):
    emit(stem + ".f32", b"".join(struct.pack("<f", v) for v in values))
    emit(stem + ".lwfc", stream)
    print(f"{stem}: {len(values)} elements -> {len(stream)} bytes")


def write_rans_fixture(stem, idx, levels, head):
    """rANS twin of a CABAC fixture: same .f32 input (not rewritten), new
    rans_<stem>.lwfc with the backend-1 header."""
    stream = head + rans_encode_payload(idx, levels)
    assert rans_decode_payload(stream[len(head):], levels, len(idx)) == idx
    emit("rans_" + stem + ".lwfc", stream)
    print(f"rans_{stem}: {len(idx)} elements -> {len(stream)} bytes")


def write_rans4_fixture(stem, idx, levels, head):
    """4-way-interleave twin (backend id 3): same .f32 input, new
    rans4_<stem>.lwfc with the backend-3 header."""
    stream = head + rans_encode_payload(idx, levels, ways=4)
    assert rans_decode_payload(stream[len(head):], levels, len(idx), ways=4) == idx
    emit("rans4_" + stem + ".lwfc", stream)
    print(f"rans4_{stem}: {len(idx)} elements -> {len(stream)} bytes")


def gen_containers(xs, img):
    """Container fixtures over the uniform_n4 input values `xs`:

    * batch_v2_uniform_n4.lwfb — spec-less v2 container, 4 uniform tiles.
      Pins that the refactored encode path still writes v2 byte-identically
      (the Rust test re-encodes and compares).
    * batch_v3_mixed.lwfb — v3 container whose directory carries one quant
      spec per tile (two different uniform ranges + one ECQ with in-band
      tables). Pins the v3 layout and the per-tile decode semantics.
    """
    n = len(xs)

    # ---- v2: uniform [0,6] N=4, tile 128 -> 4 tiles ----------------------
    c_min, c_max, levels, tile = 0.0, 6.0, 4, 128
    tiles = []
    plan = []
    for lo in range(0, n, tile):
        part = xs[lo:lo + tile]
        idx = [uniform_index(x, c_min, c_max, levels) for x in part]
        head = header_bytes(0, levels, c_min, c_max, img)
        tiles.append((len(part), encode_stream(idx, levels, head)))
        plan.append((idx, levels, len(head)))
    blob = container_bytes(tiles)
    container_self_check(blob, plan)
    emit("batch_v2_uniform_n4.lwfb", blob)
    print(f"batch_v2_uniform_n4: {n} elements -> {len(blob)} bytes")

    # ---- v3: per-tile quant specs (200 + 200 + 112 elements) -------------
    recon = [0.0, 1.0, 2.5, 6.0]
    thresholds = [0.5, 1.75, 4.25]
    cuts = [(0, 200), (200, 400), (400, n)]
    tile_specs = [
        ("uniform", 0.0, 6.0),
        ("uniform", 0.0, 2.0),
        ("ecq", 0.0, 6.0),
    ]
    tiles, plan, specs = [], [], []
    for (lo, hi), (kind, cm, cx) in zip(cuts, tile_specs):
        part = xs[lo:hi]
        if kind == "uniform":
            idx = [uniform_index(x, cm, cx, 4) for x in part]
            head = header_bytes(0, 4, cm, cx, img)
            specs.append(spec_record_uniform(cm, cx, 4))
        else:
            idx = [ecq_index(x, recon, thresholds, cm, cx) for x in part]
            head = header_bytes(1, 4, cm, cx, img, recon)
            specs.append(spec_record_ecq(cm, cx, recon, thresholds))
        tiles.append((len(part), encode_stream(idx, 4, head)))
        plan.append((idx, 4, len(head)))
    blob = container_bytes(tiles, specs=specs)
    container_self_check(blob, plan)
    emit("batch_v3_mixed.lwfb", blob)
    print(f"batch_v3_mixed: {n} elements -> {len(blob)} bytes")


def gen_video(img):
    """Temporal (container v4) fixtures: a two-frame stream session over a
    uniform [0,6] N=4 quantizer, 512 elements, tile 128 -> 4 tiles.

    * video_frame0.f32 / video_frame1.f32 — the raw frames. Frame 1's
      tiles 0-2 are frame 0 with a few indices nudged by one level (small,
      skewed residuals: inter wins); tile 3 is fresh content (residuals as
      wide as the data under the doubled 2N-1 alphabet: intra wins).
    * batch_v4_frame0.lwfb — the first frame of a session: all-intra but
      already v4, generation 1 (the generation records keep the decoder's
      reference store in lockstep from frame one).
    * batch_v4_frame1.lwfb — generation 2, tiles 0-2 inter / tile 3 intra,
      pinned by the per-tile rate decision (strictly fewer bytes or stay
      intra) exactly as the Rust encoder computes it.
    """
    c_min, c_max, levels, tile, n = 0.0, 6.0, 4, 128, 512
    boundaries = [1.0, 3.0, 5.0]
    head = header_bytes(0, levels, c_min, c_max, img)
    f0 = gen_inputs(50, n, boundaries, c_min, c_max)
    idx0 = [uniform_index(x, c_min, c_max, levels) for x in f0]

    # Frame 1, tiles 0-2: mid-bin representatives of frame 0's indices,
    # ~10% nudged one level — index-domain deltas of {-1, 0, +1}, mostly 0.
    import random

    rep = [0.2, 2.2, 4.2, 5.8]  # one safely-off-boundary value per level
    assert [uniform_index(r, c_min, c_max, levels) for r in rep] == [0, 1, 2, 3]
    rng = random.Random(51)
    f1 = []
    for j in range(3 * tile):
        u = rng.random()
        d = 1 if u < 0.05 else (-1 if u < 0.10 else 0)
        f1.append(f32(rep[min(max(idx0[j] + d, 0), levels - 1)]))
    f1 += gen_inputs(52, tile, boundaries, c_min, c_max)
    idx1 = [uniform_index(x, c_min, c_max, levels) for x in f1]

    # ---- frame 0: all intra, generation 1 --------------------------------
    tiles0, plan0 = [], []
    for lo in range(0, n, tile):
        tiles0.append((tile, encode_stream(idx0[lo:lo + tile], levels, head)))
        plan0.append((0, 1, idx0[lo:lo + tile]))
    blob0 = container_bytes(tiles0, temporal=[(m, g) for m, g, _ in plan0])
    refs = container_v4_self_check(
        blob0, plan0, [None] * 4, c_min, c_max, levels, len(head)
    )

    # ---- frame 1: per-tile rate decision against frame 0's recons --------
    tiles1, plan1 = [], []
    for t, lo in enumerate(range(0, n, tile)):
        part = idx1[lo:lo + tile]
        intra = encode_stream(part, levels, head)
        ref_idx = [uniform_index(r, c_min, c_max, levels) for r in refs[t]]
        residual = [zigzag(a - b) for a, b in zip(part, ref_idx)]
        inter = encode_stream(residual, 2 * levels - 1, head)
        if len(inter) < len(intra):  # strictly fewer bytes, else intra
            tiles1.append((tile, inter))
            plan1.append((1, 2, part))
        else:
            tiles1.append((tile, intra))
            plan1.append((0, 2, part))
    modes = [m for m, _, _ in plan1]
    assert modes == [1, 1, 1, 0], f"planned mode split changed: {modes}"
    blob1 = container_bytes(tiles1, temporal=[(m, g) for m, g, _ in plan1])
    recons1 = container_v4_self_check(
        blob1, plan1, refs, c_min, c_max, levels, len(head)
    )
    # Inter output must equal intra output bit-for-bit: both are exactly
    # the fake-quantized frame.
    want = [uniform_reconstruct(i, c_min, c_max, levels) for i in idx1]
    assert [v for tr in recons1 for v in tr] == want

    emit("video_frame0.f32", b"".join(struct.pack("<f", v) for v in f0))
    emit("video_frame1.f32", b"".join(struct.pack("<f", v) for v in f1))
    emit("batch_v4_frame0.lwfb", blob0)
    emit("batch_v4_frame1.lwfb", blob1)
    print(f"batch_v4_frame0: {n} elements -> {len(blob0)} bytes (all intra)")
    print(
        f"batch_v4_frame1: {n} elements -> {len(blob1)} bytes "
        f"({modes.count(1)} inter / {modes.count(0)} intra)"
    )


def main(check=False):
    self_check()

    n = 512
    img = 32

    # ---- uniform, N=4, clip [0, 6]: boundaries at 1, 3, 5 ----------------
    c_min, c_max, levels = 0.0, 6.0, 4
    xs = gen_inputs(42, n, [1.0, 3.0, 5.0], c_min, c_max)
    idx = [uniform_index(x, c_min, c_max, levels) for x in xs]
    assert set(idx) == {0, 1, 2, 3}, "fixture must exercise every level"
    head = header_bytes(0, levels, c_min, c_max, img)
    stream = encode_stream(idx, levels, head)
    assert decode_stream_indices(stream[len(head):], levels, n) == idx
    write_fixture("uniform_n4", xs, stream)
    write_rans_fixture(
        "uniform_n4", idx, levels, header_bytes(0, levels, c_min, c_max, img, backend=1)
    )
    write_rans4_fixture(
        "uniform_n4", idx, levels, header_bytes(0, levels, c_min, c_max, img, backend=3)
    )

    # ---- uniform, N=2 (the specialized 1-bit encoder arm): boundary 3 ----
    c_min, c_max, levels = 0.0, 6.0, 2
    xs = gen_inputs(43, n, [3.0], c_min, c_max)
    idx = [uniform_index(x, c_min, c_max, levels) for x in xs]
    assert set(idx) == {0, 1}
    head = header_bytes(0, levels, c_min, c_max, img)
    stream = encode_stream(idx, levels, head)
    assert decode_stream_indices(stream[len(head):], levels, n) == idx
    write_fixture("uniform_n2", xs, stream)
    write_rans_fixture(
        "uniform_n2", idx, levels, header_bytes(0, levels, c_min, c_max, img, backend=1)
    )
    write_rans4_fixture(
        "uniform_n2", idx, levels, header_bytes(0, levels, c_min, c_max, img, backend=3)
    )

    # ---- entropy-constrained, N=4: hand-pinned design ---------------------
    # recon/thresholds chosen like a pinned Algorithm-1 output (x̂_0 = c_min,
    # x̂_3 = c_max); exact f32 values so both sides agree bit-for-bit.
    c_min, c_max, levels = 0.0, 6.0, 4
    recon = [0.0, 1.0, 2.5, 6.0]
    thresholds = [0.5, 1.75, 4.25]
    xs = gen_inputs(44, n, thresholds, c_min, c_max)
    idx = [ecq_index(x, recon, thresholds, c_min, c_max) for x in xs]
    assert set(idx) == {0, 1, 2, 3}
    head = header_bytes(1, levels, c_min, c_max, img, recon)
    stream = encode_stream(idx, levels, head)
    assert decode_stream_indices(stream[len(head):], levels, n) == idx
    write_fixture("ecq_n4", xs, stream)
    write_rans_fixture(
        "ecq_n4", idx, levels, header_bytes(1, levels, c_min, c_max, img, recon, backend=1)
    )
    write_rans4_fixture(
        "ecq_n4", idx, levels, header_bytes(1, levels, c_min, c_max, img, recon, backend=3)
    )

    # ---- batched container fixtures (v2 spec-less + v3 per-tile specs),
    # built over the uniform_n4 input values --------------------------------
    xs_n4 = gen_inputs(42, n, [1.0, 3.0, 5.0], 0.0, 6.0)
    gen_containers(xs_n4, img)

    # ---- temporal container fixtures (v4 stream session, two frames) ------
    gen_video(img)

    # ---- write or verify --------------------------------------------------
    import os
    here = os.path.dirname(os.path.abspath(__file__))
    failures = []
    for name, blob in OUTPUTS.items():
        path = os.path.join(here, name)
        if check:
            try:
                with open(path, "rb") as f:
                    on_disk = f.read()
            except FileNotFoundError:
                failures.append(f"{name}: missing on disk")
                continue
            if on_disk != blob:
                failures.append(
                    f"{name}: committed fixture differs from generator output "
                    f"({len(on_disk)} vs {len(blob)} bytes)"
                )
        else:
            with open(path, "wb") as f:
                f.write(blob)
    if check:
        if failures:
            raise SystemExit("FIXTURE CHECK FAILED:\n  " + "\n  ".join(failures))
        print(f"fixture check passed ({len(OUTPUTS)} files byte-identical)")


if __name__ == "__main__":
    import sys
    main(check="--check" in sys.argv[1:])

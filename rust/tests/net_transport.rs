//! Integration tests for the TCP edge↔cloud transport and the pipeline's
//! failure semantics — all with synthetic codec-only stages, so they run
//! without artifacts or the `xla` feature:
//!
//! * a cloud daemon + ≥2 concurrent edge clients over real localhost TCP
//!   sockets, with the wire payloads verified byte-for-byte against the
//!   in-process loopback transport;
//! * `run_pipeline` over [`TcpTransport`] agreeing with
//!   [`LoopbackTransport`] outcome-for-outcome;
//! * a forced mid-run worker error terminating the pipeline with `Err`
//!   instead of hanging the collector (guarded by a watchdog timeout);
//! * `EdgeClient` reconnect-and-resend after the daemon drops a
//!   connection mid-stream.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};
use lwfc::coordinator::{
    run_pipeline, CloudDaemon, CloudStage, CompressedItem, EdgeClient, EdgeStage,
    LoopbackTransport, Outcome, PipelineConfig, Request, RetryPolicy, TaskKind, TcpTransport,
    Transport, WireItem, WireOutcome,
};
use lwfc::util::prop::Gen;
use lwfc::{Codec, CodecBuilder, QuantSpec};

const ELEMS: usize = 2_048;
const TILE: usize = 512;
const TASK: TaskKind = TaskKind::ClassifyAlex;

type PayloadMap = Arc<Mutex<HashMap<u64, Vec<u8>>>>;

/// Every party in these tests runs the same `Codec` session config, so
/// client-side and pipeline-side bytes are identical by construction and
/// any wire-level divergence is detectable.
fn session() -> Codec {
    CodecBuilder::new(QuantSpec::Uniform {
        c_min: 0.0,
        c_max: 2.0,
        levels: 4,
    })
    .image_size(32)
    .threads(2)
    .tile_elems(TILE)
    .force_container()
    .expect_elements(ELEMS)
    .build()
}

/// The deterministic "sensor capture" both sides regenerate from the
/// corpus index.
fn tensor_for(image_index: u64) -> Vec<f32> {
    Gen::new("net_transport", image_index).activation_vec(ELEMS, 0.5)
}

/// Encode one request through the shared session config.
fn encode_item(image_index: u64, codec: &mut Codec) -> (Vec<u8>, usize) {
    let xs = tensor_for(image_index);
    let s = codec.encode(&xs);
    (s.bytes, s.elements)
}

/// Decode + verify one item; `Some(true)` iff the reconstruction equals
/// the fake-quantized source tensor (the session's `expect_elements`
/// guards the container claim; the wire's own claim is checked here).
fn verify_item(bytes: &[u8], elements: usize, image_index: u64, codec: &mut Codec) -> Result<bool> {
    let decoded = codec.decode(bytes)?;
    let q = codec.quant_spec().materialize();
    let expect: Vec<f32> = tensor_for(image_index).iter().map(|&x| q.fake_quant(x)).collect();
    Ok(elements == decoded.values.len() && decoded.values == expect)
}

// ---------------------------------------------------------------------------
// Synthetic pipeline stages (no PJRT)

struct SynthEdge {
    codec: Codec,
    fail_after: Option<usize>,
    processed: usize,
}

impl SynthEdge {
    fn new(fail_after: Option<usize>) -> Self {
        Self {
            codec: session(),
            fail_after,
            processed: 0,
        }
    }
}

impl EdgeStage for SynthEdge {
    fn process(&mut self, requests: &[Request]) -> Result<Vec<CompressedItem>> {
        let mut out = Vec::with_capacity(requests.len());
        for r in requests {
            if let Some(limit) = self.fail_after {
                if self.processed >= limit {
                    return Err(anyhow!("synthetic edge failure after {limit} items"));
                }
            }
            self.processed += 1;
            let (bytes, elements) = encode_item(r.image_index, &mut self.codec);
            out.push(CompressedItem {
                id: r.id,
                image_index: r.image_index,
                bytes,
                elements,
                arrived: r.arrived,
                encoded: std::time::Instant::now(),
            });
        }
        Ok(out)
    }
}

struct SynthCloud {
    codec: Codec,
    fail_after: Option<usize>,
    processed: usize,
    /// Wire payloads exactly as this stage received them, by image index.
    seen: Option<PayloadMap>,
}

impl SynthCloud {
    fn new(fail_after: Option<usize>, seen: Option<PayloadMap>) -> Self {
        Self {
            codec: session(),
            fail_after,
            processed: 0,
            seen,
        }
    }
}

impl CloudStage for SynthCloud {
    fn process(&mut self, items: &[CompressedItem]) -> Result<Vec<Outcome>> {
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            if let Some(limit) = self.fail_after {
                if self.processed >= limit {
                    return Err(anyhow!("synthetic cloud failure after {limit} items"));
                }
            }
            self.processed += 1;
            if let Some(seen) = &self.seen {
                seen.lock().unwrap().insert(item.image_index, item.bytes.clone());
            }
            let correct =
                verify_item(&item.bytes, item.elements, item.image_index, &mut self.codec)?;
            out.push(Outcome {
                id: item.id,
                image_index: item.image_index,
                correct: Some(correct),
                detections: Vec::new(),
                latency_s: item.arrived.elapsed().as_secs_f64(),
                bits_per_element: item.bits_per_element(),
            });
        }
        Ok(out)
    }
}

fn pipeline_config(requests: usize) -> PipelineConfig {
    PipelineConfig {
        edge_workers: 2,
        requests,
        batch: 4,
        queue_capacity: 8,
        first_index: 0,
    }
}

/// Watchdog: a pipeline-hang regression turns into a test failure, not a
/// stuck test runner.
fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("timed out after {secs}s — the pipeline hung instead of terminating"),
    }
}

fn run_synthetic(
    transport: &dyn Transport,
    requests: usize,
    seen: Option<PayloadMap>,
) -> Result<Vec<Outcome>> {
    let out = run_pipeline(
        &pipeline_config(requests),
        transport,
        |_w| Ok(SynthEdge::new(None)),
        move || Ok(SynthCloud::new(None, seen)),
    )?;
    Ok(out.outcomes)
}

// ---------------------------------------------------------------------------
// Tests

#[test]
fn tcp_pipeline_matches_loopback_byte_for_byte() {
    with_timeout(120, || {
        let n = 32;
        let loop_seen: PayloadMap = Arc::new(Mutex::new(HashMap::new()));
        let tcp_seen: PayloadMap = Arc::new(Mutex::new(HashMap::new()));

        let loopback = LoopbackTransport::new(8, 64);
        let mut a = run_synthetic(&loopback, n, Some(Arc::clone(&loop_seen))).unwrap();

        let tcp = TcpTransport::loopback(TASK, 8, 64).unwrap();
        let mut b = run_synthetic(&tcp, n, Some(Arc::clone(&tcp_seen))).unwrap();

        a.sort_by_key(|o| o.id);
        b.sort_by_key(|o| o.id);
        assert_eq!(a.len(), n);
        assert_eq!(b.len(), n);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.image_index, y.image_index);
            assert_eq!(x.correct, Some(true));
            assert_eq!(y.correct, Some(true));
            assert_eq!(x.bits_per_element, y.bits_per_element);
        }
        // The cloud stage saw identical codec bytes through both transits.
        let la = loop_seen.lock().unwrap();
        let lb = tcp_seen.lock().unwrap();
        assert_eq!(la.len(), n);
        assert_eq!(*la, *lb, "wire payloads diverged between loopback and tcp");

        let stats = tcp.stats();
        assert_eq!(stats.items, n as u64);
        assert_eq!(stats.outcomes, n as u64);
        assert!(stats.bytes_sent > 0 && stats.bytes_received > 0);
    });
}

#[test]
fn cloud_daemon_serves_two_edge_clients_and_matches_loopback_payloads() {
    with_timeout(120, || {
        let n_per_client = 16u64;
        let n_clients = 2u64;
        let total = (n_per_client * n_clients) as usize;

        // Reference run: the same corpus range through the in-process
        // loopback pipeline, recording what the cloud stage received.
        let loop_seen: PayloadMap = Arc::new(Mutex::new(HashMap::new()));
        let loopback = LoopbackTransport::new(8, 64);
        let ref_outcomes = run_synthetic(&loopback, total, Some(Arc::clone(&loop_seen))).unwrap();
        assert_eq!(ref_outcomes.len(), total);

        // Live daemon: handler decodes + verifies, recording the payload
        // bytes exactly as they came off the socket.
        let daemon_seen: PayloadMap = Arc::new(Mutex::new(HashMap::new()));
        let handler_seen = Arc::clone(&daemon_seen);
        let daemon = CloudDaemon::start("127.0.0.1:0", TASK, 4, move |_conn| {
            let mut codec = session();
            let seen = Arc::clone(&handler_seen);
            Ok(move |item: WireItem| -> Result<WireOutcome> {
                seen.lock().unwrap().insert(item.image_index, item.bytes.clone());
                let correct =
                    verify_item(&item.bytes, item.elements as usize, item.image_index, &mut codec)?;
                Ok(WireOutcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(correct),
                    latency_s: 0.0,
                    bits_per_element: item.bytes.len() as f64 * 8.0
                        / (item.elements as f64).max(1.0),
                    detections: Vec::new(),
                })
            })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        // ≥2 concurrent edge clients splitting the corpus range.
        let mut joins = Vec::new();
        for c in 0..n_clients {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || -> (u64, Vec<WireOutcome>) {
                let mut codec = session();
                let mut client =
                    EdgeClient::connect(&addr, TASK, 4, RetryPolicy::default()).unwrap();
                let mut got = Vec::new();
                for k in 0..n_per_client {
                    let image_index = c * n_per_client + k;
                    let id = image_index; // globally unique across clients
                    let (bytes, elements) = encode_item(image_index, &mut codec);
                    got.extend(
                        client
                            .send(WireItem {
                                id,
                                image_index,
                                elements: elements as u64,
                                bytes,
                            })
                            .unwrap(),
                    );
                }
                let (rest, stats) = client.finish().unwrap();
                got.extend(rest);
                assert_eq!(stats.items_sent, n_per_client);
                assert_eq!(stats.outcomes_received, n_per_client);
                assert!(stats.bytes_sent > 0 && stats.bytes_received > 0);
                (c, got)
            }));
        }
        let mut all: Vec<WireOutcome> = Vec::new();
        for j in joins {
            let (_, got) = j.join().unwrap();
            all.extend(got);
        }
        let report = daemon.shutdown();

        // Every item produced a verified outcome.
        all.sort_by_key(|o| o.id);
        assert_eq!(all.len(), total);
        for (k, o) in all.iter().enumerate() {
            assert_eq!(o.id, k as u64);
            assert_eq!(o.correct, Some(true), "request {k} failed verification");
        }
        assert!(report.connections >= n_clients, "report: {report:?}");
        assert_eq!(report.items, total as u64);
        assert!(report.errors.is_empty(), "daemon errors: {:?}", report.errors);

        // Acceptance: what crossed the real TCP wire is byte-for-byte what
        // crossed the in-process loopback queue.
        let daemon_map = daemon_seen.lock().unwrap();
        let loop_map = loop_seen.lock().unwrap();
        assert_eq!(daemon_map.len(), total);
        assert_eq!(
            *daemon_map, *loop_map,
            "TCP wire payloads diverged from the loopback transport"
        );
    });
}

#[test]
fn failing_edge_worker_terminates_serve_with_err() {
    with_timeout(60, || {
        let loopback = LoopbackTransport::new(8, 64);
        let result = run_pipeline(
            &pipeline_config(32),
            &loopback,
            // Worker 0 dies after 3 items; worker 1 is healthy. Before the
            // supervisor refactor this deadlocked the collector, which
            // waited forever for outcomes the dead worker never produced.
            |w| Ok(SynthEdge::new((w == 0).then_some(3))),
            || Ok(SynthCloud::new(None, None)),
        );
        let err = result.expect_err("pipeline must fail when an edge worker errors");
        assert!(
            format!("{err:#}").contains("edge worker"),
            "unexpected error: {err:#}"
        );
    });
}

#[test]
fn failing_cloud_worker_terminates_serve_with_err_on_both_transports() {
    with_timeout(120, || {
        for tcp in [false, true] {
            let transport: Box<dyn Transport> = if tcp {
                Box::new(TcpTransport::loopback(TASK, 8, 64).unwrap())
            } else {
                Box::new(LoopbackTransport::new(8, 64))
            };
            let result = run_pipeline(
                &pipeline_config(32),
                transport.as_ref(),
                |_w| Ok(SynthEdge::new(None)),
                || Ok(SynthCloud::new(Some(5), None)),
            );
            let err = result.expect_err("pipeline must fail when the cloud worker errors");
            assert!(
                format!("{err:#}").contains("cloud worker"),
                "unexpected error (tcp={tcp}): {err:#}"
            );
        }
    });
}

#[test]
fn edge_client_reconnects_and_resends_after_connection_drop() {
    with_timeout(120, || {
        let n = 10u64;
        // The first connection dies after 2 items (handler error drops the
        // socket); later connections are healthy.
        let daemon = CloudDaemon::start("127.0.0.1:0", TASK, 2, move |conn| {
            let mut codec = session();
            let mut handled = 0u32;
            Ok(move |item: WireItem| -> Result<WireOutcome> {
                if conn == 0 {
                    handled += 1;
                    if handled > 2 {
                        return Err(anyhow!("injected connection failure"));
                    }
                }
                let correct =
                    verify_item(&item.bytes, item.elements as usize, item.image_index, &mut codec)?;
                Ok(WireOutcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(correct),
                    latency_s: 0.0,
                    bits_per_element: 0.0,
                    detections: Vec::new(),
                })
            })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        let mut codec = session();
        let retry = RetryPolicy {
            attempts: 10,
            backoff: Duration::from_millis(5),
            ..RetryPolicy::default()
        };
        let mut client = EdgeClient::connect(&addr, TASK, 4, retry).unwrap();
        let mut got = Vec::new();
        for id in 0..n {
            let (bytes, elements) = encode_item(id, &mut codec);
            got.extend(
                client
                    .send(WireItem {
                        id,
                        image_index: id,
                        elements: elements as u64,
                        bytes,
                    })
                    .unwrap(),
            );
        }
        let (rest, stats) = client.finish().unwrap();
        got.extend(rest);
        let report = daemon.shutdown();

        got.sort_by_key(|o| o.id);
        assert_eq!(got.len(), n as usize, "every item must eventually resolve");
        for (k, o) in got.iter().enumerate() {
            assert_eq!(o.id, k as u64);
            assert_eq!(o.correct, Some(true));
        }
        assert!(
            stats.reconnects >= 1,
            "client never reconnected: {stats:?}"
        );
        assert!(
            report.connections >= 2,
            "daemon saw {} connections, expected a reconnect",
            report.connections
        );
        assert!(!report.errors.is_empty(), "injected failure not recorded");
    });
}

//! Executable parity check between [`lwfc::consts`] (the single source
//! of truth for wire/container constants) and the mirrored constant
//! block at the top of `tests/golden/gen_golden.py`. The same pairing is
//! checked textually by `cargo xtask analyze` (lint 3); this test makes
//! the invariant fail `cargo test` too, so a drift cannot slip through a
//! run that skips the xtask pass.

use lwfc::consts;
use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;

/// Parse the generator's module-level `NAME = literal` lines. First
/// occurrence wins, which is the mirror block — every later rebinding of
/// an upper-case name (none today) would be shadowed, not trusted.
fn python_consts() -> HashMap<String, String> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/gen_golden.py");
    let text =
        fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let mut out = HashMap::new();
    for line in text.lines() {
        let Some((name, value)) = line.split_once(" = ") else {
            continue;
        };
        let name = name.trim();
        let const_like = !name.is_empty()
            && name.chars().next().is_some_and(|c| c.is_ascii_uppercase())
            && name.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_');
        if !const_like {
            continue;
        }
        let value = value.split('#').next().unwrap_or("").trim().to_string();
        out.entry(name.to_string()).or_insert(value);
    }
    out
}

fn num(m: &HashMap<String, String>, name: &str) -> u64 {
    let v = m
        .get(name)
        .unwrap_or_else(|| panic!("{name} missing from gen_golden.py's mirror block"));
    v.parse()
        .unwrap_or_else(|_| panic!("{name} must stay a plain integer literal, got `{v}`"))
}

fn magic(m: &HashMap<String, String>, name: &str) -> String {
    m.get(name)
        .unwrap_or_else(|| panic!("{name} missing from gen_golden.py's mirror block"))
        .clone()
}

#[test]
fn golden_generator_mirrors_container_consts() {
    let m = python_consts();
    let rust_magic = String::from_utf8(consts::BATCH_MAGIC.to_vec()).expect("ascii magic");
    assert_eq!(magic(&m, "BATCH_MAGIC"), format!("b\"{rust_magic}\""));
    assert_eq!(num(&m, "BATCH_MIN_VERSION"), u64::from(consts::BATCH_MIN_VERSION));
    assert_eq!(num(&m, "BATCH_VERSION_PLAIN"), u64::from(consts::BATCH_VERSION_PLAIN));
    assert_eq!(num(&m, "BATCH_VERSION"), u64::from(consts::BATCH_VERSION));
    assert_eq!(num(&m, "BATCH_VERSION_TEMPORAL"), u64::from(consts::BATCH_VERSION_TEMPORAL));
}

#[test]
fn golden_generator_mirrors_entropy_backend_ids() {
    let m = python_consts();
    assert_eq!(num(&m, "ENTROPY_ID_CABAC"), u64::from(consts::ENTROPY_ID_CABAC));
    assert_eq!(num(&m, "ENTROPY_ID_RANS"), u64::from(consts::ENTROPY_ID_RANS));
    assert_eq!(num(&m, "ENTROPY_ID_RANS4"), u64::from(consts::ENTROPY_ID_RANS4));
}

#[test]
fn golden_generator_mirrors_wire_protocol_consts() {
    let m = python_consts();
    let rust_magic = String::from_utf8(consts::NET_MAGIC.to_vec()).expect("ascii magic");
    assert_eq!(magic(&m, "NET_MAGIC"), format!("b\"{rust_magic}\""));
    assert_eq!(num(&m, "NET_VERSION"), u64::from(consts::NET_VERSION));
    assert_eq!(num(&m, "NET_MIN_VERSION"), u64::from(consts::NET_MIN_VERSION));
    assert_eq!(num(&m, "FRAME_KIND_ITEM"), u64::from(consts::FRAME_KIND_ITEM));
    assert_eq!(num(&m, "FRAME_KIND_OUTCOME"), u64::from(consts::FRAME_KIND_OUTCOME));
    assert_eq!(num(&m, "FRAME_KIND_BUSY"), u64::from(consts::FRAME_KIND_BUSY));
    assert_eq!(num(&m, "FRAME_KIND_RESET"), u64::from(consts::FRAME_KIND_RESET));
}

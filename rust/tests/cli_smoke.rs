//! CLI smoke tests: run the real `lwfc` binary (`CARGO_BIN_EXE_lwfc`) on
//! temp files and check `list`, `encode`, and `decode` end to end, in both
//! the legacy single-stream and the tiled batched wire formats.

use std::path::{Path, PathBuf};
use std::process::Command;

use lwfc::codec::UniformQuantizer;

fn lwfc() -> Command {
    Command::new(env!("CARGO_BIN_EXE_lwfc"))
}

fn temp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lwfc_cli_smoke_{}_{name}", std::process::id()));
    p
}

fn write_f32(path: &Path, xs: &[f32]) {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).unwrap();
}

fn read_f32(path: &Path) -> Vec<f32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn test_tensor(n: usize) -> Vec<f32> {
    // Deterministic activation-like values spanning below/inside/above the
    // clip range used in the tests.
    (0..n)
        .map(|i| ((i as f32 * 0.377).sin() * 4.0 + 2.0) * if i % 13 == 0 { -0.25 } else { 1.0 })
        .collect()
}

#[test]
fn list_prints_experiments() {
    let out = lwfc().arg("list").output().unwrap();
    assert!(out.status.success(), "list failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("fig2"), "missing fig2 in: {stdout}");
    assert!(stdout.contains("sec3e"), "missing sec3e in: {stdout}");
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = lwfc().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown command"), "stderr: {stderr}");
}

#[test]
fn encode_decode_roundtrip_single_stream() {
    let n = 4096usize;
    let xs = test_tensor(n);
    let input = temp_path("single.f32");
    let stream = temp_path("single.lwfc");
    let output = temp_path("single.out.f32");
    write_f32(&input, &xs);

    let enc = lwfc()
        .args(["encode", "--input"])
        .arg(&input)
        .arg("--output")
        .arg(&stream)
        .args(["--levels", "4", "--c-min", "0", "--c-max", "6"])
        .output()
        .unwrap();
    assert!(
        enc.status.success(),
        "encode failed: {}",
        String::from_utf8_lossy(&enc.stderr)
    );

    let dec = lwfc()
        .args(["decode", "--input"])
        .arg(&stream)
        .arg("--output")
        .arg(&output)
        .args(["--elements", &n.to_string()])
        .output()
        .unwrap();
    assert!(
        dec.status.success(),
        "decode failed: {}",
        String::from_utf8_lossy(&dec.stderr)
    );

    let got = read_f32(&output);
    let q = UniformQuantizer::new(0.0, 6.0, 4);
    assert_eq!(got.len(), n);
    for (i, (&x, &y)) in xs.iter().zip(&got).enumerate() {
        assert_eq!(y, q.fake_quant(x), "element {i}");
    }
    for p in [input, stream, output] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn encode_decode_roundtrip_batched() {
    let n = 40_000usize;
    let xs = test_tensor(n);
    let input = temp_path("batched.f32");
    let stream = temp_path("batched.lwfc");
    let output = temp_path("batched.out.f32");
    write_f32(&input, &xs);

    let enc = lwfc()
        .args(["encode", "--input"])
        .arg(&input)
        .arg("--output")
        .arg(&stream)
        .args(["--levels", "4", "--c-min", "0", "--c-max", "6"])
        .args(["--threads", "4", "--tile", "4096"])
        .output()
        .unwrap();
    assert!(
        enc.status.success(),
        "batched encode failed: {}",
        String::from_utf8_lossy(&enc.stderr)
    );
    let stdout = String::from_utf8_lossy(&enc.stdout);
    assert!(stdout.contains("substreams"), "stdout: {stdout}");

    // Batched containers are self-describing: no --elements needed.
    let dec = lwfc()
        .args(["decode", "--input"])
        .arg(&stream)
        .arg("--output")
        .arg(&output)
        .args(["--threads", "4"])
        .output()
        .unwrap();
    assert!(
        dec.status.success(),
        "batched decode failed: {}",
        String::from_utf8_lossy(&dec.stderr)
    );

    let got = read_f32(&output);
    let q = UniformQuantizer::new(0.0, 6.0, 4);
    assert_eq!(got.len(), n);
    for (i, (&x, &y)) in xs.iter().zip(&got).enumerate() {
        assert_eq!(y, q.fake_quant(x), "element {i}");
    }
    for p in [input, stream, output] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn encode_rans_decode_auto_detects() {
    // `--entropy rans` at encode time; decode carries no flag and must
    // auto-detect the backend from the stream header (both the legacy
    // single-stream layout and the batched container).
    for threads in ["1", "4"] {
        let n = 20_000usize;
        let xs = test_tensor(n);
        let input = temp_path(&format!("rans{threads}.f32"));
        let stream = temp_path(&format!("rans{threads}.lwfc"));
        let output = temp_path(&format!("rans{threads}.out.f32"));
        write_f32(&input, &xs);

        let enc = lwfc()
            .args(["encode", "--input"])
            .arg(&input)
            .arg("--output")
            .arg(&stream)
            .args(["--levels", "4", "--c-min", "0", "--c-max", "6"])
            .args(["--entropy", "rans", "--threads", threads, "--tile", "4096"])
            .output()
            .unwrap();
        assert!(
            enc.status.success(),
            "rans encode failed: {}",
            String::from_utf8_lossy(&enc.stderr)
        );
        let stdout = String::from_utf8_lossy(&enc.stdout);
        assert!(stdout.contains("rans entropy"), "stdout: {stdout}");

        let mut dec_cmd = lwfc();
        dec_cmd
            .args(["decode", "--input"])
            .arg(&stream)
            .arg("--output")
            .arg(&output);
        if threads == "1" {
            dec_cmd.args(["--elements", &n.to_string()]);
        }
        let dec = dec_cmd.output().unwrap();
        assert!(
            dec.status.success(),
            "rans decode failed: {}",
            String::from_utf8_lossy(&dec.stderr)
        );
        let stdout = String::from_utf8_lossy(&dec.stdout);
        assert!(stdout.contains("rans entropy"), "decode stdout: {stdout}");

        let got = read_f32(&output);
        let q = UniformQuantizer::new(0.0, 6.0, 4);
        assert_eq!(got.len(), n);
        for (i, (&x, &y)) in xs.iter().zip(&got).enumerate() {
            assert_eq!(y, q.fake_quant(x), "element {i} (threads {threads})");
        }

        // Pinning the wrong backend with --entropy is a hard error.
        let bad = lwfc()
            .args(["decode", "--input"])
            .arg(&stream)
            .arg("--output")
            .arg(&output)
            .args(["--elements", &n.to_string(), "--entropy", "cabac"])
            .output()
            .unwrap();
        assert!(!bad.status.success(), "--entropy cabac accepted a rans stream");
        let stderr = String::from_utf8_lossy(&bad.stderr);
        assert!(stderr.contains("rans"), "stderr: {stderr}");

        for p in [input, stream, output] {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[test]
fn encode_rans4_decode_auto_detects() {
    // The 4-way interleaved backend rides the same flag surface:
    // `--entropy rans4` at encode time, auto-detection at decode time,
    // and a hard error when the decoder pins any other backend —
    // including the 2-way rANS sibling, whose payload layout differs.
    for threads in ["1", "4"] {
        let n = 20_000usize;
        let xs = test_tensor(n);
        let input = temp_path(&format!("rans4_{threads}.f32"));
        let stream = temp_path(&format!("rans4_{threads}.lwfc"));
        let output = temp_path(&format!("rans4_{threads}.out.f32"));
        write_f32(&input, &xs);

        let enc = lwfc()
            .args(["encode", "--input"])
            .arg(&input)
            .arg("--output")
            .arg(&stream)
            .args(["--levels", "4", "--c-min", "0", "--c-max", "6"])
            .args(["--entropy", "rans4", "--threads", threads, "--tile", "4096"])
            .output()
            .unwrap();
        assert!(
            enc.status.success(),
            "rans4 encode failed: {}",
            String::from_utf8_lossy(&enc.stderr)
        );
        let stdout = String::from_utf8_lossy(&enc.stdout);
        assert!(stdout.contains("rans4 entropy"), "stdout: {stdout}");

        let mut dec_cmd = lwfc();
        dec_cmd
            .args(["decode", "--input"])
            .arg(&stream)
            .arg("--output")
            .arg(&output);
        if threads == "1" {
            dec_cmd.args(["--elements", &n.to_string()]);
        }
        let dec = dec_cmd.output().unwrap();
        assert!(
            dec.status.success(),
            "rans4 decode failed: {}",
            String::from_utf8_lossy(&dec.stderr)
        );
        let stdout = String::from_utf8_lossy(&dec.stdout);
        assert!(stdout.contains("rans4 entropy"), "decode stdout: {stdout}");

        let got = read_f32(&output);
        let q = UniformQuantizer::new(0.0, 6.0, 4);
        assert_eq!(got.len(), n);
        for (i, (&x, &y)) in xs.iter().zip(&got).enumerate() {
            assert_eq!(y, q.fake_quant(x), "element {i} (threads {threads})");
        }

        // Pinning either other backend with --entropy is a hard error.
        for pin in ["cabac", "rans"] {
            let bad = lwfc()
                .args(["decode", "--input"])
                .arg(&stream)
                .arg("--output")
                .arg(&output)
                .args(["--elements", &n.to_string(), "--entropy", pin])
                .output()
                .unwrap();
            assert!(
                !bad.status.success(),
                "--entropy {pin} accepted a rans4 stream"
            );
            let stderr = String::from_utf8_lossy(&bad.stderr);
            assert!(stderr.contains("rans4"), "stderr: {stderr}");
        }

        for p in [input, stream, output] {
            let _ = std::fs::remove_file(p);
        }
    }
}

#[test]
fn encode_decode_roundtrip_empty_batched() {
    // A zero-element tensor must survive the batched container round trip
    // (the container ships one empty substream carrying the codec header).
    let input = temp_path("empty.f32");
    let stream = temp_path("empty.lwfc");
    let output = temp_path("empty.out.f32");
    write_f32(&input, &[]);

    let enc = lwfc()
        .args(["encode", "--input"])
        .arg(&input)
        .arg("--output")
        .arg(&stream)
        .args(["--levels", "4", "--c-min", "0", "--c-max", "6"])
        .args(["--threads", "2", "--tile", "64"])
        .output()
        .unwrap();
    assert!(
        enc.status.success(),
        "empty encode failed: {}",
        String::from_utf8_lossy(&enc.stderr)
    );

    let dec = lwfc()
        .args(["decode", "--input"])
        .arg(&stream)
        .arg("--output")
        .arg(&output)
        .output()
        .unwrap();
    assert!(
        dec.status.success(),
        "empty decode failed: {}",
        String::from_utf8_lossy(&dec.stderr)
    );
    assert_eq!(read_f32(&output).len(), 0);
    for p in [input, stream, output] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn encode_tile_design_writes_v3_and_decodes() {
    // `--design model --clip-granularity tile` writes the v3 container
    // (one designed quantizer per tile); decode is self-describing and
    // reports the per-tile specs. Heterogeneous input so the design is
    // non-trivial.
    let n = 12_288usize;
    let xs: Vec<f32> = (0..n)
        .map(|i| {
            let base = ((i as f32 * 0.377).sin().abs()) * 1.5;
            base + [0.0f32, 6.0, 12.0][(i / 4096) % 3]
        })
        .collect();
    let input = temp_path("tiledesign.f32");
    let stream = temp_path("tiledesign.lwfc");
    let output = temp_path("tiledesign.out.f32");
    write_f32(&input, &xs);

    let enc = lwfc()
        .args(["encode", "--input"])
        .arg(&input)
        .arg("--output")
        .arg(&stream)
        .args(["--levels", "4", "--c-max", "20", "--tile", "4096"])
        .args(["--design", "model", "--clip-granularity", "tile"])
        .output()
        .unwrap();
    assert!(
        enc.status.success(),
        "tile-design encode failed: {}",
        String::from_utf8_lossy(&enc.stderr)
    );
    let stdout = String::from_utf8_lossy(&enc.stdout);
    assert!(stdout.contains("model design @ tile"), "stdout: {stdout}");
    let blob = std::fs::read(&stream).unwrap();
    assert_eq!(&blob[..4], b"LWFB");
    assert_eq!(blob[4], 3, "per-tile design must write container v3");

    let dec = lwfc()
        .args(["decode", "--input"])
        .arg(&stream)
        .arg("--output")
        .arg(&output)
        .output()
        .unwrap();
    assert!(
        dec.status.success(),
        "tile-design decode failed: {}",
        String::from_utf8_lossy(&dec.stderr)
    );
    let stdout = String::from_utf8_lossy(&dec.stdout);
    assert!(
        stdout.contains("per-tile designed quantizer"),
        "decode stdout: {stdout}"
    );
    let got = read_f32(&output);
    assert_eq!(got.len(), n);
    // Per-tile ranges track the offsets: each tile's reconstructions stay
    // near its own support instead of spanning [0, 20].
    for (t, offset) in [(0usize, 0.0f32), (1, 6.0), (2, 12.0)] {
        for i in t * 4096..(t + 1) * 4096 {
            assert!(
                (got[i] - xs[i]).abs() < 1.2,
                "tile {t} (offset {offset}) element {i}: {} vs {}",
                got[i],
                xs[i]
            );
        }
    }

    // Static design at tile granularity is a usage error.
    let bad = lwfc()
        .args(["encode", "--input"])
        .arg(&input)
        .arg("--output")
        .arg(&stream)
        .args(["--levels", "4", "--c-max", "20"])
        .args(["--clip-granularity", "tile"])
        .output()
        .unwrap();
    assert!(!bad.status.success(), "static tile design must be rejected");

    for p in [input, stream, output] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn serve_and_edge_advertise_network_modes() {
    // `--help` exits non-zero by design (usage goes through the error
    // path); what matters is that the network modes are documented.
    let serve = lwfc().args(["serve", "--help"]).output().unwrap();
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&serve.stdout),
        String::from_utf8_lossy(&serve.stderr)
    );
    assert!(text.contains("--listen"), "serve help: {text}");
    assert!(text.contains("--transport"), "serve help: {text}");
    assert!(text.contains("--entropy"), "serve help: {text}");
    assert!(text.contains("rans4"), "serve help: {text}");

    let edge = lwfc().args(["edge", "--help"]).output().unwrap();
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&edge.stdout),
        String::from_utf8_lossy(&edge.stderr)
    );
    assert!(text.contains("--connect"), "edge help: {text}");
    assert!(text.contains("--window"), "edge help: {text}");
    assert!(text.contains("--entropy"), "edge help: {text}");
    assert!(text.contains("rans4"), "edge help: {text}");
    assert!(text.contains("--video"), "edge help: {text}");
    assert!(text.contains("--hold"), "edge help: {text}");

    let encode = lwfc().args(["encode", "--help"]).output().unwrap();
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&encode.stdout),
        String::from_utf8_lossy(&encode.stderr)
    );
    assert!(text.contains("--entropy"), "encode help: {text}");
    assert!(text.contains("rans"), "encode help: {text}");
    assert!(text.contains("rans4"), "encode help: {text}");
    assert!(text.contains("--frames"), "encode help: {text}");
    assert!(text.contains("--inter"), "encode help: {text}");

    let decode = lwfc().args(["decode", "--help"]).output().unwrap();
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&decode.stdout),
        String::from_utf8_lossy(&decode.stderr)
    );
    assert!(text.contains("--entropy"), "decode help: {text}");
    assert!(text.contains("rans4"), "decode help: {text}");
}

#[test]
fn encode_inter_is_smaller_and_decodes_identically() {
    // The acceptance proxy for temporal coding: two correlated frames,
    // encoded once intra-only and once as a stream session
    // (`--frames 2 --inter`, same quantizer). Inter must cost strictly
    // fewer bytes and reconstruct byte-identical output.
    let per_frame = 4096usize;
    let frame0 = test_tensor(per_frame);
    let mut xs = frame0.clone();
    // Frame 1 drifts a little from frame 0 — far below the quantizer
    // step (6/3 = 2), so most indices repeat and residuals are ~zero.
    xs.extend(frame0.iter().enumerate().map(|(i, &x)| x + 0.01 * (i as f32 * 0.7).sin()));
    let input = temp_path("video.f32");
    let intra = temp_path("video.intra.lwfc");
    let inter = temp_path("video.inter.lwfc");
    let out_intra = temp_path("video.intra.out.f32");
    let out_inter = temp_path("video.inter.out.f32");
    write_f32(&input, &xs);

    let quant: [&str; 8] = [
        "--levels", "4", "--c-min", "0", "--c-max", "6", "--tile", "1024",
    ];
    for (flags, path) in [(&["--frames", "2"][..], &intra), (&["--frames", "2", "--inter"][..], &inter)] {
        let enc = lwfc()
            .args(["encode", "--input"])
            .arg(&input)
            .arg("--output")
            .arg(path)
            .args(quant)
            .args(["--threads", "2"])
            .args(flags)
            .output()
            .unwrap();
        assert!(
            enc.status.success(),
            "encode {flags:?} failed: {}",
            String::from_utf8_lossy(&enc.stderr)
        );
    }
    let intra_blob = std::fs::read(&intra).unwrap();
    let inter_blob = std::fs::read(&inter).unwrap();
    assert_eq!(intra_blob[4], 2, "intra frames stay container v2");
    assert_eq!(inter_blob[4], 4, "session frames are container v4");
    assert!(
        inter_blob.len() < intra_blob.len(),
        "inter stream not smaller: {} vs {} bytes",
        inter_blob.len(),
        intra_blob.len()
    );

    // `decode --inter` walks a concatenation of containers through one
    // decode session; the pre-v4 intra stream goes through the same path.
    for (path, out) in [(&intra, &out_intra), (&inter, &out_inter)] {
        let dec = lwfc()
            .args(["decode", "--input"])
            .arg(path)
            .arg("--output")
            .arg(out)
            .args(["--inter"])
            .output()
            .unwrap();
        assert!(
            dec.status.success(),
            "decode failed: {}",
            String::from_utf8_lossy(&dec.stderr)
        );
    }
    let a = std::fs::read(&out_intra).unwrap();
    let b = std::fs::read(&out_inter).unwrap();
    assert_eq!(a, b, "inter and intra reconstructions must be byte-equal");
    let got = read_f32(&out_inter);
    let q = UniformQuantizer::new(0.0, 6.0, 4);
    assert_eq!(got.len(), xs.len());
    for (i, (&x, &y)) in xs.iter().zip(&got).enumerate() {
        assert_eq!(y, q.fake_quant(x), "element {i}");
    }

    for p in [input, intra, inter, out_intra, out_inter] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn decode_legacy_without_elements_is_an_error() {
    let n = 256usize;
    let xs = test_tensor(n);
    let input = temp_path("noelem.f32");
    let stream = temp_path("noelem.lwfc");
    write_f32(&input, &xs);
    let enc = lwfc()
        .args(["encode", "--input"])
        .arg(&input)
        .arg("--output")
        .arg(&stream)
        .args(["--levels", "4", "--c-max", "6"])
        .output()
        .unwrap();
    assert!(enc.status.success());

    let dec = lwfc()
        .args(["decode", "--input"])
        .arg(&stream)
        .arg("--output")
        .arg(&temp_path("noelem.out.f32"))
        .output()
        .unwrap();
    assert!(!dec.status.success(), "decode without --elements must fail");
    let stderr = String::from_utf8_lossy(&dec.stderr);
    assert!(stderr.contains("--elements"), "stderr: {stderr}");
    for p in [input, stream] {
        let _ = std::fs::remove_file(p);
    }
}

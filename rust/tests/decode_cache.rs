//! Content-addressed decode-cache properties over the `Codec` façade:
//!
//! * a cache-enabled decode is **bit-exact** with a cache-disabled decode
//!   of the same bytes — cold (miss+insert) and warm (hit) — for any
//!   entropy backend, tile size, and thread count;
//! * the hit counters prove the entropy decoder was skipped on repeats
//!   (every tile of a warm decode hits, none miss, payload bytes are
//!   reported saved);
//! * v4 **inter** tiles bypass the cache entirely (they decode against
//!   per-connection reference state, so their payload bytes do not
//!   determine their reconstruction);
//! * tiles that fail validation are never inserted;
//! * eviction keeps the resident bytes inside the configured budget;
//! * two tenants with different salts sharing one cache never observe
//!   each other's entries.

use std::sync::Arc;

use lwfc::codec::{DecodeCache, EntropyKind};
use lwfc::prop_assert;
use lwfc::util::prop::{prop_check, Gen};
use lwfc::{CodecBuilder, QuantSpec};

fn uniform(levels: usize, c_max: f32) -> QuantSpec {
    QuantSpec::Uniform {
        c_min: 0.0,
        c_max,
        levels,
    }
}

fn batched(entropy: EntropyKind, threads: usize, tile: usize) -> CodecBuilder {
    CodecBuilder::new(uniform(4, 2.0))
        .image_size(32)
        .entropy(entropy)
        .threads(threads)
        .tile_elems(tile)
        .force_container()
}

#[test]
fn cached_decode_is_bit_exact_across_backends_tiles_and_threads() {
    prop_check("decode_cache_bit_exact", 24, |g: &mut Gen| {
        let n = g.usize_in(256, 8_000);
        let tile = g.usize_in(64, 1_024);
        let threads = g.usize_in(1, 4);
        let entropy = if g.u64() % 2 == 0 {
            EntropyKind::Cabac
        } else {
            EntropyKind::Rans
        };
        let xs = g.activation_vec(n, 0.5);
        let encoded = batched(entropy, threads, tile).build().encode(&xs);

        let plain = batched(entropy, threads, tile)
            .build()
            .decode(&encoded.bytes)
            .map_err(|e| e.to_string())?;
        let mut cached = batched(entropy, threads, tile)
            .decode_cache(16 << 20)
            .build();
        let cold = cached.decode(&encoded.bytes).map_err(|e| e.to_string())?;
        let warm = cached.decode(&encoded.bytes).map_err(|e| e.to_string())?;

        prop_assert!(
            cold.values == plain.values,
            "cold cached decode diverged (n={n} tile={tile} t={threads} {entropy})"
        );
        prop_assert!(
            warm.values == plain.values,
            "warm cached decode diverged (n={n} tile={tile} t={threads} {entropy})"
        );
        prop_assert!(
            cold.info.cache_hits == 0 && cold.info.cache_misses == cold.info.substreams as u64,
            "cold decode counters: {} hits / {} misses over {} tiles",
            cold.info.cache_hits,
            cold.info.cache_misses,
            cold.info.substreams
        );
        prop_assert!(
            warm.info.cache_hits == warm.info.substreams as u64 && warm.info.cache_misses == 0,
            "warm decode counters: {} hits / {} misses over {} tiles",
            warm.info.cache_hits,
            warm.info.cache_misses,
            warm.info.substreams
        );
        Ok(())
    });
}

#[test]
fn hit_counters_prove_entropy_decode_skipped_on_repeats() {
    let xs = Gen::new("decode_cache_counters", 0).activation_vec(4_096, 0.5);
    let encoded = batched(EntropyKind::Cabac, 2, 512).build().encode(&xs);

    let cache = Arc::new(DecodeCache::new(16 << 20));
    let mut codec = batched(EntropyKind::Cabac, 2, 512)
        .decode_cache_shared(cache.clone())
        .build();

    let cold = codec.decode(&encoded.bytes).unwrap();
    assert_eq!(cold.info.cache_hits, 0);
    assert_eq!(cold.info.cache_misses, cold.info.substreams as u64);
    assert_eq!(cold.info.cache_bytes_saved, 0);
    assert_eq!(cache.entries(), cold.info.substreams);

    let warm = codec.decode(&encoded.bytes).unwrap();
    assert_eq!(warm.info.cache_hits, warm.info.substreams as u64);
    assert_eq!(warm.info.cache_misses, 0);
    // Every payload byte of the container skipped the entropy decoder:
    // the container is prelude + directory + payloads, so the saved bytes
    // are the whole blob minus its metadata.
    let dir_len = lwfc::codec::header::BATCH_PRELUDE_BYTES
        + encoded.substreams * lwfc::codec::header::DIR_ENTRY_BYTES;
    assert!(warm.info.cache_bytes_saved > 0);
    assert!(warm.info.cache_bytes_saved <= (encoded.bytes.len() - dir_len) as u64);
    assert_eq!(warm.values, cold.values);

    // The shared cache's lifetime stats agree with the per-decode deltas.
    let stats = cache.stats();
    assert_eq!(stats.hits, warm.info.cache_hits);
    assert_eq!(stats.misses, cold.info.cache_misses);
    assert_eq!(stats.bytes_saved, warm.info.cache_bytes_saved);
}

#[test]
fn inter_tiles_bypass_the_cache() {
    // A correlated frame sequence through a stream session (container
    // v4): later frames carry inter tiles, which must never consult the
    // cache — only the frame's intra tiles count as hits or misses.
    let mut g = Gen::new("decode_cache_inter", 0);
    let n = 4_096usize;
    let mut seq = vec![g.activation_vec(n, 0.5)];
    for _ in 1..3 {
        let noise = g.activation_vec(n, 0.5);
        let prev = seq.last().unwrap();
        seq.push(
            prev.iter()
                .zip(&noise)
                .map(|(&x, &e)| x + 0.02 * (e - 0.25))
                .collect(),
        );
    }
    let session = || {
        CodecBuilder::new(uniform(8, 2.0))
            .threads(2)
            .tile_elems(512)
            .stream_session()
    };
    let mut enc = session().build();
    let blobs: Vec<Vec<u8>> = seq.iter().map(|f| enc.encode(f).bytes).collect();
    assert!(
        enc.temporal_stats().unwrap().inter_tiles > 0,
        "sequence never engaged inter coding"
    );

    let cache = Arc::new(DecodeCache::new(16 << 20));
    let mut cached_dec = session().decode_cache_shared(cache.clone()).build();
    let mut plain_dec = session().build();
    let mut saw_inter = false;
    for blob in &blobs {
        let d = cached_dec.decode(blob).unwrap();
        assert_eq!(d.values, plain_dec.decode(blob).unwrap().values);
        // Inter tiles count in neither column: the cache only ever sees
        // the frame's intra tiles.
        assert_eq!(
            d.info.cache_hits + d.info.cache_misses,
            (d.info.substreams - d.info.inter_substreams) as u64,
            "inter tiles leaked into the cache counters"
        );
        saw_inter |= d.info.inter_substreams > 0;
    }
    assert!(saw_inter, "no decoded frame carried inter tiles");
    // And no inter reconstruction was retained: every entry came from an
    // intra tile (at most one per intra tile decoded).
    let intra_total: usize = {
        let mut dec = session().build();
        blobs
            .iter()
            .map(|b| {
                let i = dec.decode(b).unwrap().info;
                i.substreams - i.inter_substreams
            })
            .sum()
    };
    assert!(cache.entries() <= intra_total);
}

#[test]
fn corrupt_tiles_are_never_inserted() {
    let xs = Gen::new("decode_cache_corrupt", 0).activation_vec(4_096, 0.5);
    let encoded = batched(EntropyKind::Cabac, 2, 512).build().encode(&xs);
    let dir_len = lwfc::codec::header::BATCH_PRELUDE_BYTES
        + encoded.substreams * lwfc::codec::header::DIR_ENTRY_BYTES;
    // Flip a payload byte: exactly one tile fails its checksum.
    let mut bad = encoded.bytes.clone();
    let victim_byte = dir_len + (bad.len() - dir_len) / 2;
    bad[victim_byte] ^= 0x5A;

    let cache = Arc::new(DecodeCache::new(16 << 20));
    let mut codec = batched(EntropyKind::Cabac, 2, 512)
        .tolerant(true)
        .decode_cache_shared(cache.clone())
        .build();
    let d = codec.decode(&bad).unwrap();
    assert_eq!(d.info.failures.len(), 1, "{:?}", d.info.failures);
    // The corrupt tile failed validation before the cache path: only the
    // healthy tiles were inserted.
    assert_eq!(cache.entries(), d.info.substreams - 1);
    // Re-decoding the damaged container: every healthy tile hits, the
    // corrupt tile still fails — it never became a cache entry.
    let again = codec.decode(&bad).unwrap();
    assert_eq!(again.info.cache_hits, (d.info.substreams - 1) as u64);
    assert_eq!(again.info.failures.len(), 1);
    assert_eq!(cache.entries(), d.info.substreams - 1);
}

#[test]
fn eviction_keeps_resident_bytes_inside_the_budget() {
    // A budget far smaller than the working set: decodes stay correct,
    // entries rotate, and the resident total never exceeds the budget.
    let cache = Arc::new(DecodeCache::new(1 << 20));
    let mut codec = batched(EntropyKind::Cabac, 2, 1_024)
        .decode_cache_shared(cache.clone())
        .build();
    for i in 0..24u64 {
        let xs = Gen::new("decode_cache_evict", i).activation_vec(16_384, 0.5);
        let encoded = batched(EntropyKind::Cabac, 2, 1_024).build().encode(&xs);
        let plain = batched(EntropyKind::Cabac, 2, 1_024)
            .build()
            .decode(&encoded.bytes)
            .unwrap();
        let d = codec.decode(&encoded.bytes).unwrap();
        assert_eq!(d.values, plain.values, "tensor {i} diverged under eviction");
        assert!(
            cache.resident_bytes() <= cache.budget_bytes(),
            "tensor {i}: resident {} exceeds budget {}",
            cache.resident_bytes(),
            cache.budget_bytes()
        );
    }
    assert!(cache.stats().evictions > 0, "working set never overflowed");
}

#[test]
fn tenants_with_different_salts_never_share_entries() {
    let xs = Gen::new("decode_cache_salt", 0).activation_vec(4_096, 0.5);
    let encoded = batched(EntropyKind::Cabac, 2, 512).build().encode(&xs);

    let cache = Arc::new(DecodeCache::new(16 << 20));
    let mut tenant_a = batched(EntropyKind::Cabac, 2, 512)
        .decode_cache_shared(cache.clone())
        .cache_salt(0xA11CE)
        .build();
    let mut tenant_b = batched(EntropyKind::Cabac, 2, 512)
        .decode_cache_shared(cache.clone())
        .cache_salt(0xB0B)
        .build();

    let a_cold = tenant_a.decode(&encoded.bytes).unwrap();
    assert_eq!(a_cold.info.cache_misses, a_cold.info.substreams as u64);
    assert_eq!(
        tenant_a.decode(&encoded.bytes).unwrap().info.cache_hits,
        a_cold.info.substreams as u64
    );
    // Tenant B decodes the *same bytes* tenant A just populated the
    // cache with — and must see none of A's entries.
    let b_cold = tenant_b.decode(&encoded.bytes).unwrap();
    assert_eq!(
        b_cold.info.cache_hits, 0,
        "tenant B probed tenant A's entries"
    );
    assert_eq!(b_cold.info.cache_misses, b_cold.info.substreams as u64);
    assert_eq!(b_cold.values, a_cold.values);
    // B's own repeats hit B's own entries; the cache now holds both
    // tenants' copies side by side.
    assert_eq!(
        tenant_b.decode(&encoded.bytes).unwrap().info.cache_hits,
        b_cold.info.substreams as u64
    );
    assert_eq!(cache.entries(), 2 * a_cold.info.substreams);
}

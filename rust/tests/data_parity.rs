//! Python ↔ Rust corpus parity: the Rust generators must reproduce the
//! statistics the Python side recorded in the manifest at build time, and
//! the corpora must have the documented structural properties.

use lwfc::data;
use lwfc::runtime::Manifest;
use lwfc::util::math::Welford;

#[test]
fn class_corpus_is_deterministic_and_balanced() {
    let (xs, ys) = data::gen_class_batch(data::VAL_SEED, 0, 100);
    let (xs2, _) = data::gen_class_batch(data::VAL_SEED, 0, 100);
    assert_eq!(xs, xs2);
    for c in 0..10 {
        assert_eq!(ys.iter().filter(|&&y| y == c).count(), 10);
    }
}

#[test]
fn corpus_pixel_statistics_are_stable() {
    // Pixel mean ~0.5 (by construction), variance dominated by the
    // grating/contrast/noise mix.
    let mut w = Welford::new();
    let (xs, _) = data::gen_class_batch(data::VAL_SEED, 0, 64);
    for &v in &xs {
        w.push(v as f64);
    }
    assert!((w.mean - 0.5).abs() < 0.05, "pixel mean {}", w.mean);
    assert!(
        w.variance() > 0.02 && w.variance() < 0.2,
        "pixel var {}",
        w.variance()
    );
}

#[test]
fn detect_corpus_invariants() {
    let (_, gts) = data::gen_detect_batch(data::VAL_SEED, 0, 64);
    let mut class_seen = [false; 3];
    for boxes in &gts {
        assert!(!boxes.is_empty() && boxes.len() <= 3);
        for b in boxes {
            class_seen[b.class] = true;
            assert!(b.w >= 11.9 && b.w <= 24.1);
        }
    }
    assert!(class_seen.iter().all(|&s| s), "all classes appear in 64 scenes");
}

#[test]
fn split_stats_match_manifest_within_tolerance() {
    // The manifest stores the Python-side split-layer stats over its val
    // stream. Regenerating the same stream in Rust and pushing it through
    // the same edge artifact must reproduce them. (This effectively pins
    // cross-language image equality: a single divergent pixel pattern
    // shifts these moments.)
    let Ok(m) = Manifest::load(&Manifest::default_dir()) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let rt = lwfc::runtime::Runtime::cpu().unwrap();
    let s = m.resnet_split(2).unwrap();
    let edge = rt.load(&s.edge).unwrap();
    let b = m.serve_batch;

    let mut w = Welford::new();
    let n_imgs = 128usize; // python used 512; moments converge well before
    for start in (0..n_imgs).step_by(b) {
        let (xs, _) = data::gen_class_batch(m.val_seed, start as u64, b);
        let feat = edge
            .run1(&[&lwfc::tensor::Tensor::new(&[b, 32, 32, 3], xs)])
            .unwrap();
        for &v in feat.data() {
            w.push(v as f64);
        }
    }
    let tol_mean = 0.05 * s.stats.var.sqrt();
    assert!(
        (w.mean - s.stats.mean).abs() < tol_mean,
        "mean {} vs manifest {}",
        w.mean,
        s.stats.mean
    );
    assert!(
        (w.variance() - s.stats.var).abs() < 0.15 * s.stats.var,
        "var {} vs manifest {}",
        w.variance(),
        s.stats.var
    );
}

#[test]
fn alex_split_is_nonnegative_resnet_split_is_leaky() {
    let Ok(m) = Manifest::load(&Manifest::default_dir()) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    // Manifest min values encode the activation family: plain ReLU has
    // min == 0, leaky has min < 0 (paper's AlexNet-vs-ResNet distinction).
    assert_eq!(m.alex.stats.min, 0.0, "alex split must be ReLU (min 0)");
    assert!(
        m.resnet_split(2).unwrap().stats.min < 0.0,
        "resnet split must be leaky (min < 0)"
    );
    assert!(m.detect.stats.min < 0.0, "detect split must be leaky");
}

//! Scalar↔SIMD seam tests, from outside the crate: every vector kernel
//! in `lwfc::codec::simd` must be bit-exact against its scalar twin on
//! adversarial inputs (NaN, ±inf, subnormals, exact clip boundaries,
//! epsilon-straddlers, every vector-tail length), and the kernels must
//! compose to exactly what the `Codec` façade produces. The suite is
//! meaningful under both dispatch settings: in a normal run it
//! differential-tests the dispatched AVX2/SSE2 paths against the scalar
//! reference; under `LWFC_FORCE_SCALAR=1` (the CI fallback job) it
//! additionally pins that the dispatcher honors the override.

use lwfc::codec::simd::{self, scalar};
use lwfc::codec::{design_ecq, EcqParams, EntropyKind, NonUniformQuantizer, UniformQuantizer};
use lwfc::prop_assert;
use lwfc::util::prop::{prop_check, Gen};
use lwfc::util::rng::SplitMix64;
use lwfc::{CodecBuilder, QuantSpec};

/// Adversarial f32 soup: NaN, ±inf, subnormals, exact boundaries,
/// values epsilon-straddling `c_min`/`c_max`, tiny offsets, and
/// ordinary in/out-of-range mass.
fn adversarial(n: usize, c_min: f32, c_max: f32, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    let span = c_max - c_min;
    (0..n)
        .map(|_| match rng.next_u64() % 12 {
            0 => f32::NAN,
            1 => f32::INFINITY,
            2 => f32::NEG_INFINITY,
            3 => f32::MIN_POSITIVE / 2.0, // subnormal
            4 => -f32::MIN_POSITIVE / 2.0,
            5 => c_min,
            6 => c_max,
            7 => c_min - f32::EPSILON * span,
            8 => c_max + f32::EPSILON * span,
            9 => c_min + span * (rng.next_f64() as f32) * 1e-6,
            _ => c_min - span * 0.25 + span * 1.5 * rng.next_f64() as f32,
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn dispatched_uniform_kernels_match_their_scalar_twins() {
    prop_check("ext_simd_uniform", 50, |g: &mut Gen| {
        let levels = *g.choice(&[2usize, 3, 4, 8, 17, 64, 255, 509]);
        let c_min = g.f32_in(-8.0, 2.0);
        let c_max = c_min + g.f32_in(0.1, 20.0);
        let n = g.usize_in(0, 700); // crosses every 4- and 8-lane tail
        let q = UniformQuantizer::new(c_min, c_max, levels);
        let xs = adversarial(n, c_min, c_max, g.usize_in(0, 1 << 30) as u64);

        let mut fast = vec![0u16; n];
        let mut slow = vec![0u16; n];
        simd::quantize_slice(&q, &xs, &mut fast);
        scalar::quantize_slice(&q, &xs, &mut slow);
        prop_assert!(fast == slow, "quantize diverged (levels={levels}, n={n})");

        let mut rf = vec![0f32; n];
        let mut rs = vec![0f32; n];
        simd::reconstruct_slice(&q, &fast, &mut rf);
        scalar::reconstruct_slice(&q, &slow, &mut rs);
        prop_assert!(bits(&rf) == bits(&rs), "reconstruct diverged (levels={levels})");

        let mut ff = vec![0f32; n];
        let mut fs = vec![0f32; n];
        simd::fake_quant_slice(&q, &xs, &mut ff);
        scalar::fake_quant_slice(&q, &xs, &mut fs);
        prop_assert!(bits(&ff) == bits(&fs), "fake_quant diverged (levels={levels})");
        // Fused fake-quant == quantize ∘ reconstruct, bit for bit.
        prop_assert!(bits(&ff) == bits(&rf), "fused path diverged from composition");

        // And all of it equals the per-element public methods.
        for (i, &x) in xs.iter().enumerate() {
            prop_assert!(fast[i] == q.index(x), "index method diverged at {i}");
            prop_assert!(
                ff[i].to_bits() == q.fake_quant(x).to_bits(),
                "fake_quant method diverged at {i}"
            );
        }
        Ok(())
    });
}

#[test]
fn dispatched_nonuniform_kernel_matches_designed_quantizers() {
    // Real Algorithm-1 designs, plus degenerate duplicate thresholds.
    prop_check("ext_simd_nonuniform", 15, |g: &mut Gen| {
        let levels = g.usize_in(2, 8);
        let train = g.activation_vec(8_192, 0.4);
        let d = design_ecq(&train, 0.0, 2.0, EcqParams::pinned(levels, 0.02));
        let mut q = d.quantizer;
        if g.bool() && q.thresholds.len() >= 2 {
            q.thresholds[1] = q.thresholds[0];
        }
        let n = g.usize_in(0, 500);
        let xs = adversarial(n, q.c_min, q.c_max, g.usize_in(0, 1 << 30) as u64);
        let mut fast = vec![0u16; n];
        let mut slow = vec![0u16; n];
        simd::nonuniform_index_slice(&q, &xs, &mut fast);
        scalar::nonuniform_index_slice(&q, &xs, &mut slow);
        prop_assert!(fast == slow, "nonuniform index diverged (levels={levels})");
        for (i, &x) in xs.iter().enumerate() {
            prop_assert!(fast[i] == q.index(x), "index method diverged at {i}");
        }
        Ok(())
    });
}

#[test]
fn nonuniform_kernel_falls_back_above_the_linear_scan_regime() {
    // Past the linear-scan width the dispatcher must agree with the
    // binary-search scalar path rather than mis-vectorize.
    let levels = NonUniformQuantizer::LINEAR_SCAN_MAX_THRESHOLDS + 10;
    let q = NonUniformQuantizer {
        recon: (0..=levels).map(|i| i as f32).collect(),
        thresholds: (0..levels).map(|i| i as f32 + 0.5).collect(),
        c_min: 0.0,
        c_max: levels as f32,
    };
    let xs = adversarial(333, q.c_min, q.c_max, 7);
    let mut fast = vec![0u16; xs.len()];
    let mut slow = vec![0u16; xs.len()];
    simd::nonuniform_index_slice(&q, &xs, &mut fast);
    scalar::nonuniform_index_slice(&q, &xs, &mut slow);
    assert_eq!(fast, slow);
}

#[test]
fn tu_bit_count_matches_scalar_across_alphabets_and_tails() {
    prop_check("ext_simd_tu_bits", 40, |g: &mut Gen| {
        let levels = *g.choice(&[2usize, 3, 4, 8, 255, 509]);
        let n = g.usize_in(0, 3_000);
        let mut rng = SplitMix64::new(g.usize_in(0, 1 << 30) as u64);
        let idx: Vec<u16> = (0..n).map(|_| (rng.next_u64() % levels as u64) as u16).collect();
        let fast = simd::tu_bit_count(&idx, levels);
        let slow = scalar::tu_bit_count(&idx, levels);
        prop_assert!(
            fast == slow,
            "tu bits diverged: {fast} vs {slow} (levels={levels}, n={n})"
        );
        Ok(())
    });
}

#[test]
fn kernels_compose_to_the_codec_facade_bit_for_bit() {
    // The façade's encode (SIMD quantize feeding the entropy stage) and
    // decode (entropy stage feeding SIMD reconstruct) must equal the
    // kernel composition on ordinary activations — for every backend.
    prop_check("ext_simd_facade", 12, |g: &mut Gen| {
        let n = g.usize_in(1, 12_000);
        let levels = *g.choice(&[2usize, 4, 8]);
        let scale = g.f32_in(0.05, 2.0);
        let xs = g.activation_vec(n, scale);
        let q = UniformQuantizer::new(0.0, 2.0, levels);
        let spec = QuantSpec::Uniform {
            c_min: 0.0,
            c_max: 2.0,
            levels,
        };
        let mut want_idx = vec![0u16; n];
        simd::quantize_slice(&q, &xs, &mut want_idx);
        let mut want_vals = vec![0f32; n];
        simd::reconstruct_slice(&q, &want_idx, &mut want_vals);
        for entropy in [EntropyKind::Cabac, EntropyKind::Rans, EntropyKind::Rans4] {
            let mut codec = CodecBuilder::new(spec.clone())
                .image_size(32)
                .entropy(entropy)
                .expect_elements(n)
                .build();
            let stream = codec.encode(&xs);
            let (idx, _) = codec.decode_indices(&stream.bytes).map_err(|e| e.to_string())?;
            prop_assert!(idx == want_idx, "{entropy}: façade indices diverge from kernels");
            let decoded = codec.decode(&stream.bytes).map_err(|e| e.to_string())?;
            prop_assert!(
                bits(&decoded.values) == bits(&want_vals),
                "{entropy}: façade reconstruction diverges from kernels"
            );
        }
        Ok(())
    });
}

#[test]
fn dispatcher_honors_the_scalar_override() {
    let a = simd::active();
    assert!(
        ["scalar", "sse2", "avx2"].contains(&a),
        "unknown kernel set {a}"
    );
    if simd::force_scalar() {
        assert_eq!(a, "scalar", "LWFC_FORCE_SCALAR=1 must pin the scalar path");
    }
}

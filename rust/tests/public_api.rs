//! Public-API snapshot for the `Codec` façade: the exported surface of
//! `codec::api` (and the error taxonomy in `codec::error`) is pinned
//! item-by-item, so accidental surface growth — a new pub fn, struct, or
//! trait slipping into the façade — fails CI until the snapshot is
//! deliberately updated here.
//!
//! Two layers:
//! 1. a compile-time existence check (the `use` list below breaks if an
//!    item is renamed or removed);
//! 2. a source-level scan of the façade modules comparing every `pub`
//!    item name against the pinned snapshot (catches *additions*, which
//!    a compile-time check cannot).

// Layer 1: every façade item is nameable from the crate root.
#[allow(unused_imports)]
use lwfc::{
    sniff, Codec, CodecBuilder, CodecError, DecodeInfo, Decoded, EncodeInfo, Encoded, FormatInfo,
    QuantSpec, StreamFormat, TemporalStats,
};

/// Extract `pub fn|struct|enum|trait|const|type <name>` item names from a
/// source file, in order of appearance (methods inside `impl` blocks
/// included — they are API surface too).
fn pub_items(source: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in source.lines() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix("pub ") else {
            continue;
        };
        for kw in ["fn ", "struct ", "enum ", "trait ", "const ", "type "] {
            if let Some(after) = rest.strip_prefix(kw) {
                let name: String = after
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !name.is_empty() {
                    out.push(name);
                }
            }
        }
    }
    out
}

fn read_module(rel: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(rel);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

#[test]
fn facade_surface_is_pinned() {
    let got = pub_items(&read_module("src/codec/api.rs"));
    // Enum variants and struct fields are not API items the scanner
    // tracks (they carry no `pub fn|struct|...` prefix); everything else
    // is pinned in order of appearance.
    let want = vec![
        // format sniffing
        "StreamFormat",
        "FormatInfo",
        "sniff",
        // builder
        "CodecBuilder",
        "new",
        "image_size",
        "detection",
        "entropy",
        "tile_elems",
        "threads",
        "tile_designer",
        "design",
        "tolerant",
        "force_container",
        "stream_session",
        "expect_elements",
        "decode_cache",
        "decode_cache_shared",
        "cache_salt",
        "build",
        // session + result types
        "Codec",
        "Encoded",
        "bits_per_element",
        "EncodeInfo",
        "bits_per_element",
        "Decoded",
        "DecodeInfo",
        "is_clean",
        "corrupted_tiles",
        "TemporalStats",
        "residual_bits_per_element",
        // session methods
        "builder",
        "quant_spec",
        "entropy",
        "encodes_container",
        "has_tile_designer",
        "is_stream_session",
        "set_quant",
        "reset_stream",
        "temporal_stats",
        "encode",
        "encode_to",
        "decode",
        "decode_into",
        "decode_indices",
    ];
    let want: Vec<String> = want.into_iter().map(String::from).collect();
    assert_eq!(
        got, want,
        "codec::api public surface changed — if intentional, update this snapshot \
         (and the README Library API section)"
    );
}

#[test]
fn error_taxonomy_surface_is_pinned() {
    let got = pub_items(&read_module("src/codec/error.rs"));
    let want: Vec<String> = [
        "CodecError",
        "header",
        "directory",
        "payload",
        "design",
        "invalid",
        "with_tile",
        "tile",
        "is_tile_local",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    assert_eq!(
        got, want,
        "codec::error public surface changed — if intentional, update this snapshot"
    );
}

#[test]
fn crate_root_reexports_the_facade() {
    let lib = read_module("src/lib.rs");
    for item in [
        "Codec",
        "CodecBuilder",
        "CodecError",
        "Decoded",
        "DecodeInfo",
        "Encoded",
        "EncodeInfo",
        "FormatInfo",
        "StreamFormat",
        "QuantSpec",
        "TemporalStats",
        "sniff",
    ] {
        assert!(
            lib.contains(item),
            "crate root no longer re-exports `{item}`"
        );
    }
}

#[test]
fn scanner_sees_through_indentation_but_not_comments() {
    let src = "impl X {\n    pub fn a(&self) {}\n}\n/// pub fn not_real\npub struct B;\n";
    assert_eq!(pub_items(src), vec!["a".to_string(), "B".to_string()]);
}

//! Loom model checks for the concurrency primitives the coordinator
//! leans on: [`lwfc::util::threadpool::BoundedQueue`] (the pipeline's
//! backpressure conduit) and the self-pipe fallback waker's AtomicBool
//! protocol (`coordinator::net::readiness::fallback`).
//!
//! These tests only compile under `--cfg loom`; the loom crate is NOT
//! declared in Cargo.toml (the offline build resolves no external
//! crates), so the nightly CI job appends a
//! `[target.'cfg(loom)'.dependencies]` entry on the fly and runs:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom
//! ```
#![cfg(loom)]

use loom::sync::atomic::{AtomicBool, Ordering};
use loom::sync::Arc;
use loom::thread;
use lwfc::util::threadpool::BoundedQueue;

#[test]
fn bounded_queue_spsc_fifo_and_close() {
    loom::model(|| {
        // Capacity 1 forces the producer through the not_full condvar on
        // the second push, so the backpressure handshake is explored.
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let tx = q.clone();
        let producer = thread::spawn(move || {
            tx.push(1).unwrap();
            tx.push(2).unwrap();
            tx.close();
        });
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1, 2]);
    });
}

#[test]
fn bounded_queue_close_push_race_never_loses_accepted_items() {
    loom::model(|| {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let tx = q.clone();
        let closer = q.clone();
        let push = thread::spawn(move || tx.push(7));
        let close = thread::spawn(move || closer.close());
        let accepted = push.join().unwrap().is_ok();
        close.join().unwrap();
        // Whatever the interleaving, an accepted item is drainable after
        // close, a rejected push leaves nothing, and the drained queue
        // reports exhaustion rather than blocking.
        match (accepted, q.pop_up_to(8)) {
            (true, Some(batch)) => assert_eq!(batch, vec![7]),
            (false, None) => {}
            (accepted, drained) => panic!("accepted={accepted} drained={drained:?}"),
        }
        assert!(q.pop().is_none());
    });
}

/// Transliteration of `readiness::fallback::Poller::wait`'s flag
/// protocol: consume a pending wake and skip the nap, else nap (modeled
/// by a yield — loom does not model time) and clear the flag. Returns
/// whether the nap was skipped.
fn wait_step(pending: &AtomicBool) -> bool {
    if !pending.swap(false, Ordering::SeqCst) {
        thread::yield_now();
        pending.store(false, Ordering::SeqCst);
        false
    } else {
        true
    }
}

#[test]
fn fallback_waker_wake_before_wait_skips_the_nap() {
    loom::model(|| {
        let pending = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&pending);
        let waker = thread::spawn(move || flag.store(true, Ordering::SeqCst));
        waker.join().unwrap();
        // join() establishes happens-before: a completed wake() must be
        // visible to the next wait and must skip the nap.
        assert!(wait_step(&pending));
        assert!(!pending.load(Ordering::SeqCst));
    });
}

#[test]
fn fallback_waker_racing_wake_is_consumed_or_cleared_never_stuck() {
    loom::model(|| {
        let pending = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&pending);
        let waker = thread::spawn(move || flag.store(true, Ordering::SeqCst));
        let consumed = wait_step(&pending);
        waker.join().unwrap();
        if consumed {
            // A consumed wake must leave the flag clear...
            assert!(!pending.load(Ordering::SeqCst));
        }
        // ...and whether the racing wake was consumed or swallowed by the
        // post-nap clear (the documented benign lost wakeup — real waits
        // are capped at 1 ms), a *sequenced* wake is never lost:
        pending.store(true, Ordering::SeqCst);
        assert!(wait_step(&pending));
    });
}

//! Cross-module property tests on the codec: end-to-end roundtrip
//! invariants, rate monotonicity, ECQ-vs-uniform relationships, and
//! failure injection on corrupted bit-streams.

use lwfc::codec::{
    batch, decode, decode_indices, design_ecq, EcqParams, Encoder, EncoderConfig, Quantizer,
    UniformQuantizer,
};
use lwfc::prop_assert;
use lwfc::util::prop::{prop_check, Gen};
use lwfc::util::threadpool::ThreadPool;

fn uniform_cfg(levels: usize, c_max: f32) -> EncoderConfig {
    EncoderConfig::classification(
        Quantizer::Uniform(UniformQuantizer::new(0.0, c_max, levels)),
        32,
    )
}

#[test]
fn roundtrip_is_exactly_fake_quant_for_any_stream() {
    prop_check("e2e_roundtrip", 40, |g: &mut Gen| {
        let n = g.usize_in(1, 20_000);
        let levels = g.usize_in(2, 12);
        let c_max = g.f32_in(0.2, 20.0);
        let scale = g.f32_in(0.05, 4.0);
        let xs = g.activation_vec(n, scale);
        let cfg = uniform_cfg(levels, c_max);
        let q = cfg.quantizer();
        let mut enc = Encoder::new(cfg);
        let stream = enc.encode(&xs);
        let (out, _) = decode(&stream.bytes, n).map_err(|e| e.to_string())?;
        for (i, (&x, &y)) in xs.iter().zip(&out).enumerate() {
            prop_assert!(y == q.fake_quant(x), "elem {i} (n={n} N={levels})");
        }
        Ok(())
    });
}

#[test]
fn decoded_indices_in_range_and_rate_reasonable() {
    prop_check("indices_range", 30, |g: &mut Gen| {
        let n = g.usize_in(64, 8192);
        let levels = g.usize_in(2, 9);
        let xs = g.activation_vec(n, 0.5);
        let mut enc = Encoder::new(uniform_cfg(levels, 2.0));
        let stream = enc.encode(&xs);
        let (idx, header) = decode_indices(&stream.bytes, n).map_err(|e| e.to_string())?;
        prop_assert!(header.levels == levels, "header levels");
        prop_assert!(
            idx.iter().all(|&i| (i as usize) < levels),
            "index out of range"
        );
        // CABAC + TU can never exceed (N-1) bits/element by much, and the
        // header adds 96 bits total.
        let bound = (levels - 1) as f64 + 0.1 + 96.0 / n as f64;
        prop_assert!(
            stream.bits_per_element() < bound,
            "rate {} over bound {bound}",
            stream.bits_per_element()
        );
        Ok(())
    });
}

#[test]
fn more_levels_never_decrease_reconstruction_quality() {
    prop_check("levels_monotone_mse", 20, |g: &mut Gen| {
        let xs = g.activation_vec(10_000, 0.4);
        let c_max = g.f32_in(1.0, 6.0);
        let mut prev_mse = f64::INFINITY;
        for levels in [2usize, 4, 8, 16, 32] {
            let q = UniformQuantizer::new(0.0, c_max, levels);
            let mse: f64 = xs
                .iter()
                .map(|&x| {
                    let c = x.clamp(0.0, c_max); // distortion vs *clipped* signal
                    ((c - q.fake_quant(x)) as f64).powi(2)
                })
                .sum::<f64>()
                / xs.len() as f64;
            prop_assert!(
                mse <= prev_mse + 1e-12,
                "MSE increased at N={levels}: {mse} > {prev_mse}"
            );
            prev_mse = mse;
        }
        Ok(())
    });
}

#[test]
fn ecq_lambda_sweep_trades_rate_for_distortion() {
    prop_check("ecq_rd_tradeoff", 10, |g: &mut Gen| {
        let train = g.activation_vec(30_000, 0.4);
        let test = g.activation_vec(8_192, 0.4);
        let mut prev_rate = f64::INFINITY;
        for lambda in [0.0, 0.01, 0.1, 1.0] {
            let d = design_ecq(&train, 0.0, 2.0, EcqParams::pinned(4, lambda));
            let q = Quantizer::NonUniform(d.quantizer);
            let mut enc = Encoder::new(EncoderConfig::classification(q, 32));
            let rate = enc.encode(&test).bits_per_element();
            // Rate must be non-increasing in λ (up to CABAC adaptivity
            // noise, allow 3%).
            prop_assert!(
                rate <= prev_rate * 1.03,
                "rate {rate} > prev {prev_rate} at λ={lambda}"
            );
            prev_rate = rate;
        }
        Ok(())
    });
}

#[test]
fn pinned_ecq_spans_range_conventional_does_not() {
    prop_check("ecq_span", 15, |g: &mut Gen| {
        let train = g.activation_vec(20_000, 0.5);
        let c_max = g.f32_in(1.0, 4.0);
        let levels = g.usize_in(3, 6);
        let p = design_ecq(&train, 0.0, c_max, EcqParams::pinned(levels, 0.02)).quantizer;
        let c = design_ecq(&train, 0.0, c_max, EcqParams::conventional(levels, 0.02)).quantizer;
        prop_assert!(p.recon[0] == 0.0 && p.recon[levels - 1] == c_max, "pin broken");
        prop_assert!(
            c.recon[levels - 1] < c_max,
            "conventional top centroid should sit below c_max"
        );
        Ok(())
    });
}

#[test]
fn corrupted_streams_never_panic() {
    prop_check("corruption", 60, |g: &mut Gen| {
        let n = g.usize_in(16, 2048);
        let xs = g.activation_vec(n, 0.5);
        let mut enc = Encoder::new(uniform_cfg(4, 2.0));
        let mut bytes = enc.encode(&xs).bytes;
        match g.usize_in(0, 2) {
            0 => {
                // truncate anywhere
                let cut = g.usize_in(0, bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                // flip a random byte
                if !bytes.is_empty() {
                    let i = g.usize_in(0, bytes.len() - 1);
                    bytes[i] ^= g.u64() as u8 | 1;
                }
            }
            _ => {
                // random garbage of the same length
                for b in bytes.iter_mut() {
                    *b = g.u64() as u8;
                }
            }
        }
        // Must return Ok (CABAC is self-synchronizing to *some* indices) or
        // Err — but never panic, and any Ok result must be in-range.
        if let Ok((vals, header)) = decode(&bytes, xs.len()) {
            prop_assert!(vals.len() == xs.len(), "length after corruption");
            for &v in &vals {
                prop_assert!(
                    v >= header.c_min && v <= header.c_max,
                    "decoded value {v} outside [{}, {}]",
                    header.c_min,
                    header.c_max
                );
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_single_element_streams() {
    for n in [0usize, 1, 2] {
        let xs = vec![0.7f32; n];
        let mut enc = Encoder::new(uniform_cfg(4, 2.0));
        let stream = enc.encode(&xs);
        let (out, _) = decode(&stream.bytes, n).unwrap();
        assert_eq!(out.len(), n);
    }
}

#[test]
fn rate_reflects_entropy_not_levels() {
    // All-zeros tensor at N=8 must cost far less than 3 bits/element.
    let xs = vec![0.0f32; 8192];
    let mut enc = Encoder::new(uniform_cfg(8, 2.0));
    let bpe = enc.encode(&xs).bits_per_element();
    assert!(bpe < 0.1, "constant tensor cost {bpe} bits/element");
}

#[test]
fn batched_decode_equals_sequential_fake_quant_for_any_shape() {
    // The tentpole equivalence property: for ANY tensor, tile size and
    // thread count, batched decode output is bit-identical to the
    // single-stream fake-quant path.
    prop_check("batch_equivalence", 30, |g: &mut Gen| {
        let n = g.usize_in(0, 60_000);
        let levels = g.usize_in(2, 10);
        let c_max = g.f32_in(0.3, 12.0);
        let tile = g.usize_in(1, 8_000);
        let threads = g.usize_in(1, 8);
        let scale = g.f32_in(0.1, 2.0);
        let xs = g.activation_vec(n, scale);
        let cfg = uniform_cfg(levels, c_max);
        let q = cfg.quantizer();
        let pool = ThreadPool::new(threads);

        let batched = batch::encode_batched(&cfg, &xs, tile, &pool);
        prop_assert!(
            batched.substreams == n.div_ceil(tile.max(1)).max(1),
            "substream count {} for n={n} tile={tile}",
            batched.substreams
        );
        // Every legitimately encoded container decodes — the empty tensor
        // ships one empty substream so its header survives the round trip.
        let (out, header) =
            batch::decode_batched(&batched.bytes, &pool).map_err(|e| e.to_string())?;
        prop_assert!(header.levels == levels, "header levels");
        prop_assert!(out.len() == n, "length {} != {n}", out.len());
        for (i, (&x, &y)) in xs.iter().zip(&out).enumerate() {
            prop_assert!(
                y == q.fake_quant(x),
                "elem {i}: {y} != fake_quant {} (n={n} tile={tile} threads={threads})",
                q.fake_quant(x)
            );
        }
        Ok(())
    });
}

#[test]
fn batched_bytes_do_not_depend_on_thread_count() {
    prop_check("batch_determinism", 10, |g: &mut Gen| {
        let n = g.usize_in(1, 20_000);
        let tile = g.usize_in(16, 4_000);
        let xs = g.activation_vec(n, 0.5);
        let cfg = uniform_cfg(4, 2.0);
        let a = batch::encode_batched(&cfg, &xs, tile, &ThreadPool::new(1));
        let b = batch::encode_batched(&cfg, &xs, tile, &ThreadPool::new(g.usize_in(2, 8)));
        prop_assert!(a.bytes == b.bytes, "bytes differ across thread counts (n={n})");
        Ok(())
    });
}

#[test]
fn corrupted_substream_directory_is_rejected_never_panics() {
    // Failure injection on the container metadata: any single corrupted
    // byte in the prelude or in the structural directory fields must turn
    // strict decode into Err (checksum-field flips may instead surface as
    // per-substream corruption); nothing may panic.
    prop_check("batch_dir_corruption", 60, |g: &mut Gen| {
        let n = g.usize_in(64, 8_000);
        let tile = g.usize_in(32, 1_024);
        let xs = g.activation_vec(n, 0.5);
        let cfg = uniform_cfg(4, 2.0);
        let pool = ThreadPool::new(g.usize_in(1, 4));
        let encoded = batch::encode_batched(&cfg, &xs, tile, &pool);

        let dir_len = lwfc::codec::header::BATCH_PRELUDE_BYTES
            + encoded.substreams * lwfc::codec::header::DIR_ENTRY_BYTES;
        let i = g.usize_in(0, dir_len - 1);
        let mut bad = encoded.bytes.clone();
        bad[i] ^= (g.u64() as u8) | 1;

        let in_checksum_field = i >= lwfc::codec::header::BATCH_PRELUDE_BYTES
            && (i - lwfc::codec::header::BATCH_PRELUDE_BYTES)
                % lwfc::codec::header::DIR_ENTRY_BYTES
                >= 8;
        let strict = batch::decode_batched(&bad, &pool);
        prop_assert!(
            strict.is_err(),
            "corrupt metadata byte {i} accepted by strict decode (n={n} tile={tile})"
        );
        if in_checksum_field {
            // A flipped checksum damages exactly one substream; the
            // tolerant decoder must isolate it and keep the tensor shape.
            let (out, report) =
                batch::decode_batched_tolerant(&bad, &pool).map_err(|e| e.to_string())?;
            prop_assert!(out.len() == n, "tolerant length {}", out.len());
            let victim = (i - lwfc::codec::header::BATCH_PRELUDE_BYTES)
                / lwfc::codec::header::DIR_ENTRY_BYTES;
            prop_assert!(
                report.corrupted == vec![victim],
                "expected substream {victim} corrupted, got {:?}",
                report.corrupted
            );
        } else {
            // Structural damage: the whole container is unreadable, even
            // tolerantly — but still an Err, not a panic.
            prop_assert!(
                batch::decode_batched_tolerant(&bad, &pool).is_err(),
                "structural corruption at byte {i} not rejected"
            );
        }
        Ok(())
    });
}

#[test]
fn implausible_directory_claims_are_container_errors_for_every_decoder() {
    // A forged directory entry whose element count cannot correspond to a
    // real compressed stream (elements > MAX_ELEMS_PER_PAYLOAD_BYTE ×
    // payload bytes, checksum deliberately valid so only the plausibility
    // bound can catch it) must be rejected by the strict decoder, the
    // tolerant decoder (which would otherwise fill `elements` values — up
    // to 4 Gi per entry), and the count-only reader guarding `decode_any`.
    prop_check("batch_implausible_dir", 40, |g: &mut Gen| {
        let n = g.usize_in(64, 4_096);
        let tile = g.usize_in(32, 512);
        let xs = g.activation_vec(n, 0.5);
        let pool = ThreadPool::new(g.usize_in(1, 4));
        let encoded = batch::encode_batched(&uniform_cfg(4, 2.0), &xs, tile, &pool);

        // Rewrite one directory entry in place: huge element claim, same
        // byte_len and checksum, prelude total patched to keep the sums
        // consistent (so only plausibility validation can reject it).
        let (dir, _) = lwfc::codec::header::SubstreamDirectory::read(&encoded.bytes)
            .map_err(|e| e.to_string())?;
        let victim = g.usize_in(0, dir.entries.len() - 1);
        let over = lwfc::codec::batch::MAX_ELEMS_PER_PAYLOAD_BYTE as u32 + 1;
        let forged_elems: u32 =
            (dir.entries[victim].byte_len.saturating_mul(over)).max(1 << 30);
        let new_total = dir.total_elements - dir.entries[victim].elements as u64
            + forged_elems as u64;
        let mut bad = encoded.bytes.clone();
        bad[10..18].copy_from_slice(&new_total.to_le_bytes());
        let entry_off = lwfc::codec::header::BATCH_PRELUDE_BYTES
            + victim * lwfc::codec::header::DIR_ENTRY_BYTES;
        bad[entry_off..entry_off + 4].copy_from_slice(&forged_elems.to_le_bytes());

        prop_assert!(
            batch::decode_batched(&bad, &pool).is_err(),
            "strict decode accepted a forged element claim (victim {victim})"
        );
        prop_assert!(
            batch::decode_batched_tolerant(&bad, &pool).is_err(),
            "tolerant decode must not fill a forged element claim (victim {victim})"
        );
        prop_assert!(
            batch::batched_elements(&bad).is_err(),
            "count-only reader accepted a forged directory"
        );
        Ok(())
    });
}

#[test]
fn corrupted_payload_is_isolated_to_its_substream() {
    prop_check("batch_payload_corruption", 40, |g: &mut Gen| {
        let n = g.usize_in(256, 10_000);
        let tile = g.usize_in(64, 1_024);
        let xs = g.activation_vec(n, 0.5);
        let cfg = uniform_cfg(4, 2.0);
        let q = cfg.quantizer();
        let pool = ThreadPool::new(2);
        let encoded = batch::encode_batched(&cfg, &xs, tile, &pool);

        let dir_len = lwfc::codec::header::BATCH_PRELUDE_BYTES
            + encoded.substreams * lwfc::codec::header::DIR_ENTRY_BYTES;
        let i = g.usize_in(dir_len, encoded.bytes.len() - 1);
        let mut bad = encoded.bytes.clone();
        bad[i] ^= (g.u64() as u8) | 1;

        prop_assert!(
            batch::decode_batched(&bad, &pool).is_err(),
            "payload flip at {i} accepted by strict decode"
        );
        let (out, report) =
            batch::decode_batched_tolerant(&bad, &pool).map_err(|e| e.to_string())?;
        prop_assert!(out.len() == n, "tolerant decode length");
        prop_assert!(
            report.corrupted.len() == 1,
            "exactly one substream should fail, got {:?}",
            report.corrupted
        );
        let victim = report.corrupted[0];
        for (j, (&x, &y)) in xs.iter().zip(&out).enumerate() {
            if j / tile != victim {
                prop_assert!(
                    y == q.fake_quant(x),
                    "healthy element {j} perturbed (victim {victim})"
                );
            }
        }
        Ok(())
    });
}

//! Cross-module property tests on the codec: end-to-end roundtrip
//! invariants, rate monotonicity, ECQ-vs-uniform relationships, failure
//! injection on corrupted bit-streams — and the error-taxonomy contract:
//! every corruption class maps to its specific [`CodecError`] variant,
//! classified by `matches!`, never by message substrings. Everything
//! drives the `Codec` façade — the sole public entry point since the
//! deprecated free-function shims were removed in 0.3.0.

use lwfc::codec::{design_ecq, EcqParams, EntropyKind, Quantizer, UniformQuantizer};
use lwfc::prop_assert;
use lwfc::util::prop::{prop_check, Gen};
use lwfc::{Codec, CodecBuilder, CodecError, QuantSpec};

fn uniform(levels: usize, c_max: f32) -> QuantSpec {
    QuantSpec::Uniform {
        c_min: 0.0,
        c_max,
        levels,
    }
}

/// Single-stream session (threads 1): the legacy wire format.
fn single(quant: impl Into<QuantSpec>, elements: usize) -> Codec {
    CodecBuilder::new(quant)
        .image_size(32)
        .expect_elements(elements)
        .build()
}

/// Container session with `threads` workers and `tile`-element tiles.
fn batched(quant: impl Into<QuantSpec>, threads: usize, tile: usize) -> Codec {
    CodecBuilder::new(quant)
        .image_size(32)
        .threads(threads)
        .tile_elems(tile)
        .force_container()
        .build()
}

fn tolerant(quant: impl Into<QuantSpec>, threads: usize, tile: usize) -> Codec {
    CodecBuilder::new(quant)
        .image_size(32)
        .threads(threads)
        .tile_elems(tile)
        .force_container()
        .tolerant(true)
        .build()
}

#[test]
fn roundtrip_is_exactly_fake_quant_for_any_stream() {
    prop_check("e2e_roundtrip", 40, |g: &mut Gen| {
        let n = g.usize_in(1, 20_000);
        let levels = g.usize_in(2, 12);
        let c_max = g.f32_in(0.2, 20.0);
        let scale = g.f32_in(0.05, 4.0);
        let xs = g.activation_vec(n, scale);
        let spec = uniform(levels, c_max);
        let q = spec.materialize();
        let mut codec = single(spec, n);
        let stream = codec.encode(&xs);
        let out = codec.decode(&stream.bytes).map_err(|e| e.to_string())?.values;
        for (i, (&x, &y)) in xs.iter().zip(&out).enumerate() {
            prop_assert!(y == q.fake_quant(x), "elem {i} (n={n} N={levels})");
        }
        Ok(())
    });
}

#[test]
fn decoded_indices_in_range_and_rate_reasonable() {
    prop_check("indices_range", 30, |g: &mut Gen| {
        let n = g.usize_in(64, 8192);
        let levels = g.usize_in(2, 9);
        let xs = g.activation_vec(n, 0.5);
        let mut codec = single(uniform(levels, 2.0), n);
        let stream = codec.encode(&xs);
        let (idx, header) = codec
            .decode_indices(&stream.bytes)
            .map_err(|e| e.to_string())?;
        prop_assert!(header.levels == levels, "header levels");
        prop_assert!(
            idx.iter().all(|&i| (i as usize) < levels),
            "index out of range"
        );
        // CABAC + TU can never exceed (N-1) bits/element by much, and the
        // header adds 96 bits total.
        let bound = (levels - 1) as f64 + 0.1 + 96.0 / n as f64;
        prop_assert!(
            stream.bits_per_element() < bound,
            "rate {} over bound {bound}",
            stream.bits_per_element()
        );
        Ok(())
    });
}

#[test]
fn more_levels_never_decrease_reconstruction_quality() {
    prop_check("levels_monotone_mse", 20, |g: &mut Gen| {
        let xs = g.activation_vec(10_000, 0.4);
        let c_max = g.f32_in(1.0, 6.0);
        let mut prev_mse = f64::INFINITY;
        for levels in [2usize, 4, 8, 16, 32] {
            let q = UniformQuantizer::new(0.0, c_max, levels);
            let mse: f64 = xs
                .iter()
                .map(|&x| {
                    let c = x.clamp(0.0, c_max); // distortion vs *clipped* signal
                    ((c - q.fake_quant(x)) as f64).powi(2)
                })
                .sum::<f64>()
                / xs.len() as f64;
            prop_assert!(
                mse <= prev_mse + 1e-12,
                "MSE increased at N={levels}: {mse} > {prev_mse}"
            );
            prev_mse = mse;
        }
        Ok(())
    });
}

#[test]
fn ecq_lambda_sweep_trades_rate_for_distortion() {
    prop_check("ecq_rd_tradeoff", 10, |g: &mut Gen| {
        let train = g.activation_vec(30_000, 0.4);
        let test = g.activation_vec(8_192, 0.4);
        let mut prev_rate = f64::INFINITY;
        for lambda in [0.0, 0.01, 0.1, 1.0] {
            let d = design_ecq(&train, 0.0, 2.0, EcqParams::pinned(4, lambda));
            let mut codec = single(Quantizer::NonUniform(d.quantizer), test.len());
            let rate = codec.encode(&test).bits_per_element();
            // Rate must be non-increasing in λ (up to CABAC adaptivity
            // noise, allow 3%).
            prop_assert!(
                rate <= prev_rate * 1.03,
                "rate {rate} > prev {prev_rate} at λ={lambda}"
            );
            prev_rate = rate;
        }
        Ok(())
    });
}

#[test]
fn pinned_ecq_spans_range_conventional_does_not() {
    prop_check("ecq_span", 15, |g: &mut Gen| {
        let train = g.activation_vec(20_000, 0.5);
        let c_max = g.f32_in(1.0, 4.0);
        let levels = g.usize_in(3, 6);
        let p = design_ecq(&train, 0.0, c_max, EcqParams::pinned(levels, 0.02)).quantizer;
        let c = design_ecq(&train, 0.0, c_max, EcqParams::conventional(levels, 0.02)).quantizer;
        prop_assert!(p.recon[0] == 0.0 && p.recon[levels - 1] == c_max, "pin broken");
        prop_assert!(
            c.recon[levels - 1] < c_max,
            "conventional top centroid should sit below c_max"
        );
        Ok(())
    });
}

#[test]
fn corrupted_streams_never_panic() {
    prop_check("corruption", 60, |g: &mut Gen| {
        let n = g.usize_in(16, 2048);
        let xs = g.activation_vec(n, 0.5);
        let mut codec = single(uniform(4, 2.0), n);
        let mut bytes = codec.encode(&xs).bytes;
        match g.usize_in(0, 2) {
            0 => {
                // truncate anywhere
                let cut = g.usize_in(0, bytes.len());
                bytes.truncate(cut);
            }
            1 => {
                // flip a random byte
                if !bytes.is_empty() {
                    let i = g.usize_in(0, bytes.len() - 1);
                    bytes[i] ^= g.u64() as u8 | 1;
                }
            }
            _ => {
                // random garbage of the same length
                for b in bytes.iter_mut() {
                    *b = g.u64() as u8;
                }
            }
        }
        // Must return Ok (CABAC is self-synchronizing to *some* indices) or
        // Err — but never panic, and any Ok result must be in-range. The
        // Err side must classify as stream-scope damage: header or payload
        // (or a directory error, when garbage forges the container magic).
        match codec.decode(&bytes) {
            Ok(decoded) => {
                let header = decoded.info.header.as_ref().expect("clean decode has header");
                prop_assert!(decoded.values.len() == xs.len(), "length after corruption");
                for &v in &decoded.values {
                    prop_assert!(
                        v >= header.c_min && v <= header.c_max,
                        "decoded value {v} outside [{}, {}]",
                        header.c_min,
                        header.c_max
                    );
                }
            }
            Err(e) => {
                prop_assert!(
                    matches!(
                        e,
                        CodecError::Header { .. }
                            | CodecError::Payload { .. }
                            | CodecError::UnknownBackend { .. }
                            | CodecError::Directory { .. }
                            | CodecError::ElementCountMismatch { .. }
                            | CodecError::ImplausibleElements { .. }
                            | CodecError::SpecRecord { .. }
                    ),
                    "unexpected variant for stream corruption: {e:?}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_single_element_streams() {
    for n in [0usize, 1, 2] {
        let xs = vec![0.7f32; n];
        let mut codec = single(uniform(4, 2.0), n);
        let stream = codec.encode(&xs);
        let decoded = codec.decode(&stream.bytes).unwrap();
        assert_eq!(decoded.values.len(), n);
    }
}

#[test]
fn rate_reflects_entropy_not_levels() {
    // All-zeros tensor at N=8 must cost far less than 3 bits/element.
    let xs = vec![0.0f32; 8192];
    let mut codec = single(uniform(8, 2.0), xs.len());
    let bpe = codec.encode(&xs).bits_per_element();
    assert!(bpe < 0.1, "constant tensor cost {bpe} bits/element");
}

#[test]
fn batched_decode_equals_sequential_fake_quant_for_any_shape() {
    // The batching equivalence property: for ANY tensor, tile size and
    // thread count, batched decode output is bit-identical to the
    // single-stream fake-quant path.
    prop_check("batch_equivalence", 30, |g: &mut Gen| {
        let n = g.usize_in(0, 60_000);
        let levels = g.usize_in(2, 10);
        let c_max = g.f32_in(0.3, 12.0);
        let tile = g.usize_in(1, 8_000);
        let threads = g.usize_in(1, 8);
        let scale = g.f32_in(0.1, 2.0);
        let xs = g.activation_vec(n, scale);
        let spec = uniform(levels, c_max);
        let q = spec.materialize();
        let mut codec = batched(spec, threads, tile);

        let encoded = codec.encode(&xs);
        prop_assert!(
            encoded.substreams == n.div_ceil(tile.max(1)).max(1),
            "substream count {} for n={n} tile={tile}",
            encoded.substreams
        );
        // Every legitimately encoded container decodes — the empty tensor
        // ships one empty substream so its header survives the round trip.
        let decoded = codec.decode(&encoded.bytes).map_err(|e| e.to_string())?;
        let header = decoded.info.header.as_ref().ok_or("missing header")?;
        prop_assert!(header.levels == levels, "header levels");
        prop_assert!(decoded.values.len() == n, "length {} != {n}", decoded.values.len());
        for (i, (&x, &y)) in xs.iter().zip(&decoded.values).enumerate() {
            prop_assert!(
                y == q.fake_quant(x),
                "elem {i}: {y} != fake_quant {} (n={n} tile={tile} threads={threads})",
                q.fake_quant(x)
            );
        }
        Ok(())
    });
}

#[test]
fn decode_into_equals_fresh_decode_bit_exactly() {
    // The zero-copy serving path is not allowed to change a single bit:
    // for any tensor, format (single stream / container), backend, tile
    // size and thread count, `decode_into` through a junk-filled reused
    // buffer equals a fresh `decode` — and both equal fake-quant.
    prop_check("decode_into_equivalence", 30, |g: &mut Gen| {
        let n = g.usize_in(0, 40_000);
        let levels = g.usize_in(2, 9);
        let tile = g.usize_in(1, 6_000);
        let threads = g.usize_in(1, 6);
        let entropy = *g.choice(&[EntropyKind::Cabac, EntropyKind::Rans]);
        let container = g.bool();
        let xs = g.activation_vec(n, 0.5);

        let mut builder = CodecBuilder::new(uniform(levels, 2.0))
            .image_size(32)
            .entropy(entropy)
            .threads(threads)
            .tile_elems(tile)
            .expect_elements(n);
        if container {
            builder = builder.force_container();
        }
        let mut codec = builder.build();
        let encoded = codec.encode(&xs);

        let fresh = codec.decode(&encoded.bytes).map_err(|e| e.to_string())?;
        // Junk in the reused buffer must not leak into the result.
        let mut buf: Vec<f32> = vec![f32::NAN; g.usize_in(0, 3 * tile)];
        let info = codec
            .decode_into(&encoded.bytes, &mut buf)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            buf == fresh.values,
            "decode_into diverged from decode (n={n} tile={tile} threads={threads} \
             {entropy} container={container})"
        );
        prop_assert!(info.elements == fresh.info.elements, "info elements");
        prop_assert!(info.substreams == fresh.info.substreams, "info substreams");
        prop_assert!(info.header == fresh.info.header, "info header");
        // And a second pass through the same buffer is stable.
        codec.decode_into(&encoded.bytes, &mut buf).map_err(|e| e.to_string())?;
        prop_assert!(buf == fresh.values, "second reuse diverged");
        Ok(())
    });
}

#[test]
fn batched_bytes_do_not_depend_on_thread_count() {
    prop_check("batch_determinism", 10, |g: &mut Gen| {
        let n = g.usize_in(1, 20_000);
        let tile = g.usize_in(16, 4_000);
        let xs = g.activation_vec(n, 0.5);
        let a = batched(uniform(4, 2.0), 1, tile).encode(&xs);
        let b = batched(uniform(4, 2.0), g.usize_in(2, 8), tile).encode(&xs);
        prop_assert!(a.bytes == b.bytes, "bytes differ across thread counts (n={n})");
        Ok(())
    });
}

#[test]
fn corrupted_substream_directory_is_rejected_never_panics() {
    // Failure injection on the container metadata: any single corrupted
    // byte in the prelude or in the structural directory fields must turn
    // strict decode into Err (checksum-field flips may instead surface as
    // per-substream corruption); nothing may panic, and every failure is
    // a typed variant.
    prop_check("batch_dir_corruption", 60, |g: &mut Gen| {
        let n = g.usize_in(64, 8_000);
        let tile = g.usize_in(32, 1_024);
        let xs = g.activation_vec(n, 0.5);
        let threads = g.usize_in(1, 4);
        let mut codec = batched(uniform(4, 2.0), threads, tile);
        let encoded = codec.encode(&xs);

        let dir_len = lwfc::codec::header::BATCH_PRELUDE_BYTES
            + encoded.substreams * lwfc::codec::header::DIR_ENTRY_BYTES;
        let i = g.usize_in(0, dir_len - 1);
        let mut bad = encoded.bytes.clone();
        bad[i] ^= (g.u64() as u8) | 1;

        let in_checksum_field = i >= lwfc::codec::header::BATCH_PRELUDE_BYTES
            && (i - lwfc::codec::header::BATCH_PRELUDE_BYTES)
                % lwfc::codec::header::DIR_ENTRY_BYTES
                >= 8;
        let strict = codec.decode(&bad);
        prop_assert!(
            strict.is_err(),
            "corrupt metadata byte {i} accepted by strict decode (n={n} tile={tile})"
        );
        let mut tol = tolerant(uniform(4, 2.0), threads, tile);
        if in_checksum_field {
            // A flipped checksum damages exactly one substream; the
            // tolerant decoder must isolate it, keep the tensor shape, and
            // classify it as a checksum mismatch for that tile.
            let decoded = tol.decode(&bad).map_err(|e| e.to_string())?;
            prop_assert!(decoded.values.len() == n, "tolerant length {}", decoded.values.len());
            let victim = (i - lwfc::codec::header::BATCH_PRELUDE_BYTES)
                / lwfc::codec::header::DIR_ENTRY_BYTES;
            prop_assert!(
                decoded.info.corrupted_tiles() == vec![victim],
                "expected substream {victim} corrupted, got {:?}",
                decoded.info.corrupted_tiles()
            );
            prop_assert!(
                matches!(
                    &decoded.info.failures[..],
                    [CodecError::ChecksumMismatch { tile: Some(t), .. }] if *t == victim
                ),
                "wrong failure classification: {:?}",
                decoded.info.failures
            );
        } else {
            // Structural damage: the whole container is unreadable, even
            // tolerantly — a fatal (non-tile-local) typed error.
            let err = match tol.decode(&bad) {
                Err(e) => e,
                Ok(_) => return Err(format!("structural corruption at byte {i} not rejected")),
            };
            prop_assert!(
                !err.is_tile_local(),
                "structural corruption misclassified as tile-local: {err:?}"
            );
            prop_assert!(
                matches!(
                    err,
                    CodecError::Directory { .. }
                        | CodecError::UnknownBackend { .. }
                        | CodecError::ImplausibleElements { .. }
                ),
                "unexpected variant for directory corruption at byte {i}: {err:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn implausible_directory_claims_are_container_errors_for_every_decoder() {
    // A forged directory entry whose element count cannot correspond to a
    // real compressed stream (elements > MAX_ELEMS_PER_PAYLOAD_BYTE ×
    // payload bytes, checksum deliberately valid so only the plausibility
    // bound can catch it) must be rejected by the strict decoder and the
    // tolerant decoder (which would otherwise fill `elements` values — up
    // to 4 Gi per entry) — in both cases as the typed
    // `ImplausibleElements` variant at container scope, raised before any
    // tile decodes.
    prop_check("batch_implausible_dir", 40, |g: &mut Gen| {
        let n = g.usize_in(64, 4_096);
        let tile = g.usize_in(32, 512);
        let xs = g.activation_vec(n, 0.5);
        let threads = g.usize_in(1, 4);
        let mut codec = batched(uniform(4, 2.0), threads, tile);
        let encoded = codec.encode(&xs);

        // Rewrite one directory entry in place: huge element claim, same
        // byte_len and checksum, prelude total patched to keep the sums
        // consistent (so only plausibility validation can reject it).
        let (dir, _) = lwfc::codec::header::SubstreamDirectory::read(&encoded.bytes)
            .map_err(|e| e.to_string())?;
        let victim = g.usize_in(0, dir.entries.len() - 1);
        let over = lwfc::codec::batch::MAX_ELEMS_PER_PAYLOAD_BYTE as u32 + 1;
        let forged_elems: u32 =
            (dir.entries[victim].byte_len.saturating_mul(over)).max(1 << 30);
        let new_total = dir.total_elements - dir.entries[victim].elements as u64
            + forged_elems as u64;
        let mut bad = encoded.bytes.clone();
        bad[10..18].copy_from_slice(&new_total.to_le_bytes());
        let entry_off = lwfc::codec::header::BATCH_PRELUDE_BYTES
            + victim * lwfc::codec::header::DIR_ENTRY_BYTES;
        bad[entry_off..entry_off + 4].copy_from_slice(&forged_elems.to_le_bytes());

        prop_assert!(
            matches!(codec.decode(&bad), Err(CodecError::ImplausibleElements { tile: None, .. })),
            "strict decode accepted a forged element claim (victim {victim})"
        );
        let mut tol = tolerant(uniform(4, 2.0), threads, tile);
        prop_assert!(
            matches!(tol.decode(&bad), Err(CodecError::ImplausibleElements { .. })),
            "tolerant decode must not fill a forged element claim (victim {victim})"
        );
        // The pre-decode expectation guard hits the same wall before the
        // count comparison (the count-only reader path).
        let mut guarded = CodecBuilder::new(uniform(4, 2.0))
            .threads(threads)
            .expect_elements(n)
            .build();
        prop_assert!(
            matches!(guarded.decode(&bad), Err(CodecError::ImplausibleElements { .. })),
            "expectation guard accepted a forged directory"
        );
        Ok(())
    });
}

#[test]
fn corrupted_payload_is_isolated_to_its_substream() {
    prop_check("batch_payload_corruption", 40, |g: &mut Gen| {
        let n = g.usize_in(256, 10_000);
        let tile = g.usize_in(64, 1_024);
        let xs = g.activation_vec(n, 0.5);
        let spec = uniform(4, 2.0);
        let q = spec.materialize();
        let mut codec = batched(spec.clone(), 2, tile);
        let encoded = codec.encode(&xs);

        let dir_len = lwfc::codec::header::BATCH_PRELUDE_BYTES
            + encoded.substreams * lwfc::codec::header::DIR_ENTRY_BYTES;
        let i = g.usize_in(dir_len, encoded.bytes.len() - 1);
        let mut bad = encoded.bytes.clone();
        bad[i] ^= (g.u64() as u8) | 1;

        let strict = codec.decode(&bad);
        prop_assert!(
            strict.is_err(),
            "payload flip at {i} accepted by strict decode"
        );
        prop_assert!(
            strict.as_ref().err().map(|e| e.is_tile_local()) == Some(true),
            "payload corruption must be tile-local: {:?}",
            strict.err()
        );
        let mut tol = tolerant(spec, 2, tile);
        let decoded = tol.decode(&bad).map_err(|e| e.to_string())?;
        prop_assert!(decoded.values.len() == n, "tolerant decode length");
        let corrupted = decoded.info.corrupted_tiles();
        prop_assert!(
            corrupted.len() == 1,
            "exactly one substream should fail, got {corrupted:?}"
        );
        prop_assert!(
            decoded.info.failures[0].is_tile_local(),
            "tolerant failure must be tile-local: {:?}",
            decoded.info.failures[0]
        );
        let victim = corrupted[0];
        for (j, (&x, &y)) in xs.iter().zip(&decoded.values).enumerate() {
            if j / tile != victim {
                prop_assert!(
                    y == q.fake_quant(x),
                    "healthy element {j} perturbed (victim {victim})"
                );
            }
        }
        Ok(())
    });
}


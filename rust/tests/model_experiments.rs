//! Model ↔ measurement agreement over the real artifacts: the paper's
//! central claims, asserted as tests rather than just plotted.

use lwfc::codec::UniformQuantizer;
use lwfc::coordinator::TaskKind;
use lwfc::experiments::common::{fit_cache, ValCache};
use lwfc::modeling::{optimal_cmax, total_error};
use lwfc::runtime::Manifest;

fn cache_for(task: TaskKind, n: usize) -> Option<ValCache> {
    let m = Manifest::load(&Manifest::default_dir())
        .map_err(|e| eprintln!("SKIP: {e}"))
        .ok()?;
    Some(ValCache::build(&m, task, n).unwrap())
}

#[test]
fn analytic_error_tracks_measured_error_resnet() {
    // Fig. 5(a): the analytic e_tot curve must track the measured MSRE
    // within ~15% across the clipping range of interest, for N ∈ {2,4,8}.
    let Some(cache) = cache_for(TaskKind::ClassifyResnet { split: 2 }, 128) else {
        return;
    };
    let model = fit_cache(&cache).unwrap();
    let hi = cache.max_value();
    for levels in [2usize, 4, 8] {
        for i in 1..=8 {
            let c = hi * i as f32 / 8.0;
            let analytic = total_error(&model.pdf, 0.0, c as f64, levels);
            let q = UniformQuantizer::new(0.0, c, levels);
            let measured = cache.msre_with(|x| q.fake_quant(x));
            // The paper's own Fig. 5(b)/(c) show the curves "do not overlap
            // exactly"; what matters is tracking the minimum. 25% pointwise.
            assert!(
                (analytic - measured).abs() < 0.25 * measured.max(1e-4),
                "N={levels} c={c}: analytic {analytic} vs measured {measured}"
            );
        }
    }
}

#[test]
fn model_clipping_recovers_peak_accuracy_at_n4() {
    // Fig. 7: at N >= 4 the model-based c_max must be within 1% of the
    // empirically best accuracy (the paper's headline for fine-enough N).
    let Some(cache) = cache_for(TaskKind::ClassifyResnet { split: 2 }, 256) else {
        return;
    };
    let model = fit_cache(&cache).unwrap();
    for levels in [4usize, 6, 8] {
        let c_model = optimal_cmax(&model.pdf, 0.0, levels).c_max as f32;
        let qm = UniformQuantizer::new(0.0, c_model, levels);
        let acc_model = cache.metric_with(|x| qm.fake_quant(x)).unwrap();

        let mut acc_best = 0.0f64;
        let hi = cache.max_value();
        for i in 1..=32 {
            let c = hi * i as f32 / 32.0;
            let q = UniformQuantizer::new(0.0, c, levels);
            acc_best = acc_best.max(cache.metric_with(|x| q.fake_quant(x)).unwrap());
        }
        assert!(
            acc_best - acc_model <= 0.01 + 1e-9,
            "N={levels}: model acc {acc_model} vs best {acc_best}"
        );
    }
}

#[test]
fn coarse_quantization_without_clipping_destroys_accuracy() {
    // §III intro example: quantizing to 3 bits over the raw range (no
    // clipping, c_max = observed max) costs real accuracy, while the
    // model-clipped 3-bit quantizer recovers it.
    let Some(cache) = cache_for(TaskKind::ClassifyResnet { split: 2 }, 256) else {
        return;
    };
    let clean = cache.metric_with(|x| x).unwrap();
    let raw_max = cache.max_value();
    let q_raw = UniformQuantizer::new(0.0, raw_max, 8);
    let acc_raw = cache.metric_with(|x| q_raw.fake_quant(x)).unwrap();

    let model = fit_cache(&cache).unwrap();
    let c = optimal_cmax(&model.pdf, 0.0, 8).c_max as f32;
    let q_clip = UniformQuantizer::new(0.0, c, 8);
    let acc_clip = cache.metric_with(|x| q_clip.fake_quant(x)).unwrap();

    assert!(
        acc_clip >= acc_raw,
        "clipping should not hurt: clipped {acc_clip} vs raw {acc_raw}"
    );
    assert!(
        clean - acc_clip < 0.01 + 1e-9,
        "model-clipped 3-bit should be within 1% of clean ({acc_clip} vs {clean})"
    );
}

#[test]
fn one_bit_quantization_is_feasible_with_model_clipping() {
    // §IV-A: 1-bit quantization remains usable (paper: ~5% loss on
    // ResNet-50; our substitute networks are smaller, allow <= 12%).
    let Some(cache) = cache_for(TaskKind::ClassifyResnet { split: 2 }, 256) else {
        return;
    };
    let clean = cache.metric_with(|x| x).unwrap();
    let model = fit_cache(&cache).unwrap();
    let c = optimal_cmax(&model.pdf, 0.0, 2).c_max as f32;
    let q = UniformQuantizer::new(0.0, c, 2);
    let acc = cache.metric_with(|x| q.fake_quant(x)).unwrap();
    assert!(
        clean - acc <= 0.12,
        "1-bit loss too large: {acc} vs clean {clean}"
    );
}

#[test]
fn detection_map_survives_2bit_quantization() {
    let Some(cache) = cache_for(TaskKind::Detect, 96) else {
        return;
    };
    let clean = cache.metric_with(|x| x).unwrap();
    let model = fit_cache(&cache).unwrap();
    let c = optimal_cmax(&model.pdf, 0.0, 4).c_max as f32;
    let q = UniformQuantizer::new(0.0, c, 4);
    let quant = cache.metric_with(|x| q.fake_quant(x)).unwrap();
    assert!(
        clean - quant <= 0.05,
        "detect mAP loss at N=4: {quant} vs clean {clean}"
    );
}

//! Integration tests over the real artifacts: PJRT execution, Python↔Rust
//! data parity, codec-in-the-loop accuracy, and the serving pipeline.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise).

use lwfc::codec::{Quantizer, UniformQuantizer};
use lwfc::CodecBuilder;
use lwfc::coordinator::{
    serve, CloudConfig, EdgeConfig, QuantSpec, ServeConfig, TaskKind, TransportKind,
};
use lwfc::data;
use lwfc::eval::top1;
use lwfc::runtime::{Manifest, Runtime};
use lwfc::tensor::Tensor;

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

/// Run edge+cloud over `n` validation images with an optional quantizer in
/// the middle; return top-1 accuracy.
fn classify_accuracy(m: &Manifest, split: usize, quant: Option<&Quantizer>, n: usize) -> f64 {
    let rt = Runtime::cpu().unwrap();
    let s = m.resnet_split(split).unwrap();
    let edge = rt.load(&s.edge).unwrap();
    let cloud = rt.load(&s.cloud).unwrap();
    let b = m.serve_batch;
    let per_item: usize = s.feature[1..].iter().product();

    let mut logits_all = Vec::new();
    let mut labels_all = Vec::new();
    for start in (0..n).step_by(b) {
        let count = b.min(n - start);
        let (mut xs, ys) = data::gen_class_batch(m.val_seed, start as u64, count);
        for _ in count..b {
            let tail = xs[xs.len() - 32 * 32 * 3..].to_vec();
            xs.extend_from_slice(&tail);
        }
        let input = Tensor::new(&[b, 32, 32, 3], xs);
        let mut feat = edge.run1(&[&input]).unwrap();
        if let Some(q) = quant {
            for v in feat.data_mut() {
                *v = q.fake_quant(*v);
            }
        }
        let logits = cloud.run1(&[&feat]).unwrap();
        let classes = logits.shape()[1];
        logits_all.extend_from_slice(&logits.data()[..count * classes]);
        labels_all.extend_from_slice(&ys[..count]);
    }
    top1(&logits_all, 10, &labels_all)
}

#[test]
fn clean_accuracy_matches_python_build() {
    // Python measured top-1 over its own val stream at build time; the
    // Rust data generator + runtime must land within noise of it (data
    // parity means same images up to libm ULPs).
    let Some(m) = manifest() else { return };
    let acc = classify_accuracy(&m, 2, None, 256);
    assert!(
        (acc - m.resnet_top1).abs() < 0.05,
        "rust-side clean accuracy {acc} vs python {top}",
        top = m.resnet_top1
    );
}

#[test]
fn fakequant_artifact_matches_rust_quantizer() {
    // The L1 Pallas fakequant kernel (lowered into resnet_edge_fq) must
    // agree element-wise with the Rust UniformQuantizer — one quantizer
    // definition across all three layers.
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let s = m.resnet_split(2).unwrap();
    let edge = rt.load(&s.edge).unwrap();
    let edge_fq = rt.load(&m.resnet_edge_fq).unwrap();
    let b = m.serve_batch;

    let (xs, _) = data::gen_class_batch(m.val_seed, 0, b);
    let input = Tensor::new(&[b, 32, 32, 3], xs);
    let feat = edge.run1(&[&input]).unwrap();

    let (c_min, c_max, levels) = (0.0f32, 1.2f32, 4usize);
    let q = UniformQuantizer::new(c_min, c_max, levels);
    let scale = (levels - 1) as f32 / (c_max - c_min);
    let params = Tensor::new(&[1, 3], vec![c_min, c_max, scale]);
    let fq_out = edge_fq.run1(&[&input, &params]).unwrap();

    assert_eq!(fq_out.shape(), feat.shape());
    let mut max_err = 0.0f32;
    for (i, (&raw, &kq)) in feat.data().iter().zip(fq_out.data()).enumerate() {
        let rq = q.fake_quant(raw);
        let err = (rq - kq).abs();
        if err > max_err {
            max_err = err;
        }
        assert!(
            err < 1e-5,
            "element {i}: kernel {kq} vs rust {rq} (raw {raw})"
        );
    }
    eprintln!("fakequant parity max_err = {max_err}");
}

#[test]
fn moments_artifact_matches_welford() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let s = m.resnet_split(2).unwrap();
    let edge = rt.load(&s.edge).unwrap();
    let moments = rt.load(&m.resnet_moments).unwrap();
    let b = m.serve_batch;

    let (xs, _) = data::gen_class_batch(m.val_seed, 64, b);
    let input = Tensor::new(&[b, 32, 32, 3], xs);
    let feat = edge.run1(&[&input]).unwrap();
    let outs = moments.run(&[&feat]).unwrap();
    assert_eq!(outs.len(), 2);
    let (sum_k, sumsq_k) = (outs[0].data()[0] as f64, outs[1].data()[0] as f64);

    let sum: f64 = feat.data().iter().map(|&v| v as f64).sum();
    let sumsq: f64 = feat.data().iter().map(|&v| (v as f64) * (v as f64)).sum();
    assert!((sum_k - sum).abs() < 1e-2 * sum.abs().max(1.0), "{sum_k} vs {sum}");
    assert!((sumsq_k - sumsq).abs() < 1e-2 * sumsq.max(1.0), "{sumsq_k} vs {sumsq}");
}

#[test]
fn quantized_pipeline_through_bitstream_preserves_accuracy() {
    // Full codec in the loop (encode → bytes → decode) at N=4 with a
    // near-optimal clip range: accuracy must stay within 2% of clean.
    let Some(m) = manifest() else { return };
    let s = m.resnet_split(2).unwrap();

    // Model-based c_max from the manifest's build-time stats.
    let model = lwfc::modeling::fit_leaky(s.stats.mean, s.stats.var).unwrap();
    let c_max = lwfc::modeling::optimal_cmax(&model.pdf, 0.0, 4).c_max as f32;

    let q = Quantizer::Uniform(UniformQuantizer::new(0.0, c_max, 4));
    let clean = classify_accuracy(&m, 2, None, 128);
    let quant = classify_accuracy(&m, 2, Some(&q), 128);
    assert!(
        clean - quant < 0.02 + 1e-9,
        "N=4 model-clipped accuracy dropped too far: {quant} vs clean {clean} (c_max {c_max})"
    );
}

#[test]
fn bitstream_roundtrip_on_real_features() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    let s = m.resnet_split(2).unwrap();
    let edge = rt.load(&s.edge).unwrap();
    let b = m.serve_batch;
    let per_item: usize = s.feature[1..].iter().product();

    let (xs, _) = data::gen_class_batch(m.val_seed, 0, b);
    let feat = edge.run1(&[&Tensor::new(&[b, 32, 32, 3], xs)]).unwrap();

    let q = UniformQuantizer::new(0.0, 1.2, 4);
    let mut codec = CodecBuilder::new(q)
        .image_size(32)
        .expect_elements(per_item)
        .build();
    let mut decoded = Vec::new();
    for i in 0..b {
        let item = &feat.data()[i * per_item..(i + 1) * per_item];
        let stream = codec.encode(item);
        codec.decode_into(&stream.bytes, &mut decoded).unwrap();
        for (j, (&x, &y)) in item.iter().zip(&decoded).enumerate() {
            assert_eq!(y, q.fake_quant(x), "item {i} elem {j}");
        }
        // Coarse quantization of real features must compress well below
        // the raw 2 bits (paper: 0.6-0.8 bits/element at N=4).
        let bpe = stream.bits_per_element();
        assert!(bpe < 2.0, "bits/element {bpe}");
    }
}

#[test]
fn serving_pipeline_end_to_end() {
    let Some(m) = manifest() else { return };
    let s = m.resnet_split(2).unwrap();
    let task = TaskKind::ClassifyResnet { split: 2 };
    let cfg = ServeConfig {
        edge: EdgeConfig {
            task,
            quant: QuantSpec::Uniform {
                c_min: 0.0,
                c_max: 1.2,
                levels: 4,
            },
            entropy: lwfc::codec::EntropyKind::Cabac,
            val_seed: m.val_seed,
            batch: m.serve_batch,
            design: lwfc::codec::DesignKind::Static,
            granularity: lwfc::codec::ClipGranularity::Stream,
            adaptive: None,
            threads: 2,
            video: false,
            decode_cache_mb: 0,
        },
        cloud: CloudConfig {
            task,
            val_seed: m.val_seed,
            batch: m.serve_batch,
            obj_threshold: 0.3,
            threads: 2,
            // Cache-enabled on the real pipeline: served accuracy and the
            // loopback/tcp metric-parity assertion below double as the
            // "cache-enabled decode is bit-exact" end-to-end check.
            decode_cache: Some(std::sync::Arc::new(lwfc::codec::DecodeCache::new(8 << 20))),
            cache_salt: 0,
        },
        edge_workers: 2,
        requests: 64,
        queue_capacity: 32,
        first_index: 0,
        transport: TransportKind::Loopback,
    };
    let report = serve(&m, cfg.clone()).unwrap();
    eprintln!("{}", report.summary());
    assert_eq!(report.requests, 64);
    assert!(report.metric > 0.75, "served accuracy {}", report.metric);
    assert!(report.bits_per_element > 0.0 && report.bits_per_element < 2.5);
    assert!(report.throughput_rps > 1.0);

    // The same pipeline through a real localhost TCP socket pair must
    // produce identical task quality and record wire traffic.
    let tcp_cfg = ServeConfig {
        transport: TransportKind::Tcp,
        ..cfg
    };
    let tcp_report = serve(&m, tcp_cfg).unwrap();
    eprintln!("{}", tcp_report.summary());
    assert_eq!(tcp_report.requests, 64);
    assert!(
        (tcp_report.metric - report.metric).abs() < 1e-9,
        "tcp metric {} != loopback {}",
        tcp_report.metric,
        report.metric
    );
    assert_eq!(tcp_report.transport.name, "tcp");
    assert!(tcp_report.transport.bytes_sent > 0);
    let _ = s;
}

#[test]
fn detect_pipeline_end_to_end() {
    let Some(m) = manifest() else { return };
    let task = TaskKind::Detect;
    let cfg = ServeConfig {
        edge: EdgeConfig {
            task,
            quant: QuantSpec::Uniform {
                c_min: 0.0,
                c_max: 1.0,
                levels: 8,
            },
            entropy: lwfc::codec::EntropyKind::Rans,
            val_seed: m.val_seed,
            batch: m.serve_batch,
            design: lwfc::codec::DesignKind::Static,
            granularity: lwfc::codec::ClipGranularity::Stream,
            adaptive: None,
            threads: 2,
            video: false,
            decode_cache_mb: 0,
        },
        cloud: CloudConfig {
            task,
            val_seed: m.val_seed,
            batch: m.serve_batch,
            obj_threshold: 0.3,
            threads: 2,
            decode_cache: None,
            cache_salt: 0,
        },
        edge_workers: 1,
        requests: 48,
        queue_capacity: 32,
        first_index: 0,
        transport: TransportKind::Loopback,
    };
    let report = serve(&m, cfg).unwrap();
    eprintln!("{}", report.summary());
    assert!(report.metric > 0.3, "mAP@0.5 {} too low", report.metric);
}

//! Cross-backend differential tests: CABAC and interleaved rANS (both
//! the 2-way and the 4-way backend) are independent implementations of
//! the same entropy stage, so for ANY tensor, clip range and level count
//! they must round-trip to identical quantizer indices, report
//! consistent rates, and disagree only in payload bytes. Corruption
//! robustness is asymmetric by design — CABAC self-synchronizes to
//! *some* in-range indices, while rANS carries integrity checks
//! (final-state + full-consumption, at every interleave width) and must
//! turn truncated or corrupted payloads into typed `Err`s, never a
//! panic.
//!
//! Also covers the serving-path acceptance: a rANS-encoded stream
//! round-trips through the pipeline over a real localhost TCP transport
//! (the `lwfc` CLI leg lives in `cli_smoke.rs`). Everything drives the
//! `Codec` façade.

use lwfc::codec::{design_ecq, EcqParams, EntropyKind, Quantizer, UniformQuantizer};
use lwfc::prop_assert;
use lwfc::util::prop::{prop_check, Gen};
use lwfc::{Codec, CodecBuilder, QuantSpec};

fn uniform(levels: usize, c_max: f32) -> QuantSpec {
    QuantSpec::Uniform {
        c_min: 0.0,
        c_max,
        levels,
    }
}

fn session(quant: impl Into<QuantSpec>, entropy: EntropyKind, elements: usize) -> Codec {
    CodecBuilder::new(quant)
        .image_size(32)
        .entropy(entropy)
        .expect_elements(elements)
        .build()
}

/// Encode `xs` with all three backends and return the three streams.
fn encode_all(levels: usize, c_max: f32, xs: &[f32]) -> (Vec<u8>, Vec<u8>, Vec<u8>) {
    let cabac = session(uniform(levels, c_max), EntropyKind::Cabac, xs.len()).encode(xs);
    let rans = session(uniform(levels, c_max), EntropyKind::Rans, xs.len()).encode(xs);
    let rans4 = session(uniform(levels, c_max), EntropyKind::Rans4, xs.len()).encode(xs);
    (cabac.bytes, rans.bytes, rans4.bytes)
}

#[test]
fn backends_roundtrip_to_identical_indices() {
    prop_check("diff_identical_indices", 40, |g: &mut Gen| {
        let n = g.usize_in(0, 20_000);
        let levels = *g.choice(&[2usize, 3, 4, 8]);
        let c_max = g.f32_in(0.2, 12.0);
        let scale = g.f32_in(0.05, 3.0);
        let xs = g.activation_vec(n, scale);
        let q = Quantizer::Uniform(UniformQuantizer::new(0.0, c_max, levels));

        let (cb, rb, r4b) = encode_all(levels, c_max, &xs);
        let mut codec = session(uniform(levels, c_max), EntropyKind::Cabac, n);
        let (ci, ch) = codec.decode_indices(&cb).map_err(|e| e.to_string())?;
        let (ri, rh) = codec.decode_indices(&rb).map_err(|e| e.to_string())?;
        let (r4i, r4h) = codec.decode_indices(&r4b).map_err(|e| e.to_string())?;
        prop_assert!(ch.entropy == EntropyKind::Cabac, "cabac header backend");
        prop_assert!(rh.entropy == EntropyKind::Rans, "rans header backend");
        prop_assert!(r4h.entropy == EntropyKind::Rans4, "rans4 header backend");
        prop_assert!(ci == ri, "index mismatch (n={n} levels={levels})");
        prop_assert!(ci == r4i, "rans4 index mismatch (n={n} levels={levels})");
        // All agree with the quantizer applied directly.
        for (i, &x) in xs.iter().enumerate() {
            prop_assert!(
                ci[i] == q.index(x),
                "decoded index diverges from quantizer at {i}"
            );
        }
        // And the reconstructions agree value-for-value.
        let cv = codec.decode(&cb).map_err(|e| e.to_string())?.values;
        let rv = codec.decode(&rb).map_err(|e| e.to_string())?.values;
        let r4v = codec.decode(&r4b).map_err(|e| e.to_string())?.values;
        prop_assert!(cv == rv, "reconstruction mismatch (n={n} levels={levels})");
        prop_assert!(cv == r4v, "rans4 reconstruction mismatch (n={n} levels={levels})");
        Ok(())
    });
}

#[test]
fn backends_report_consistent_bits_per_element() {
    prop_check("diff_bpe", 25, |g: &mut Gen| {
        let n = g.usize_in(64, 30_000);
        let levels = *g.choice(&[2usize, 3, 4, 8]);
        let xs = g.activation_vec(n, 0.4);
        for entropy in [EntropyKind::Cabac, EntropyKind::Rans, EntropyKind::Rans4] {
            let stream = session(uniform(levels, 2.0), entropy, n).encode(&xs);
            let bpe = stream.bits_per_element();
            // The reported metric is exactly stream size over elements …
            let expect = stream.bytes.len() as f64 * 8.0 / n as f64;
            prop_assert!(bpe == expect, "bpe metric inconsistent for {entropy}");
            // … and stays below the raw TU ceiling plus side info (tables
            // + initial states for rANS — 16 bytes at the 4-way width —
            // and the 12-byte header for all backends).
            let side = 12.0 + 2.0 * (levels - 1) as f64 + 16.0 + 5.0;
            let bound = (levels - 1) as f64 + 0.1 + side * 8.0 / n as f64;
            prop_assert!(
                bpe < bound,
                "{entropy} rate {bpe} over bound {bound} (n={n} levels={levels})"
            );
        }
        Ok(())
    });
}

#[test]
fn backends_agree_on_ecq_streams() {
    prop_check("diff_ecq", 10, |g: &mut Gen| {
        let train = g.activation_vec(20_000, 0.4);
        let xs = g.activation_vec(8_192, 0.4);
        let levels = g.usize_in(3, 6);
        let d = design_ecq(&train, 0.0, 2.0, EcqParams::pinned(levels, 0.02));
        let cb = session(
            Quantizer::NonUniform(d.quantizer.clone()),
            EntropyKind::Cabac,
            xs.len(),
        )
        .encode(&xs);
        let rb = session(
            Quantizer::NonUniform(d.quantizer.clone()),
            EntropyKind::Rans,
            xs.len(),
        )
        .encode(&xs);
        let r4b = session(
            Quantizer::NonUniform(d.quantizer.clone()),
            EntropyKind::Rans4,
            xs.len(),
        )
        .encode(&xs);
        let mut codec = session(uniform(levels, 2.0), EntropyKind::Cabac, xs.len());
        let (ci, _) = codec.decode_indices(&cb.bytes).map_err(|e| e.to_string())?;
        let (ri, rh) = codec.decode_indices(&rb.bytes).map_err(|e| e.to_string())?;
        let (r4i, r4h) = codec.decode_indices(&r4b.bytes).map_err(|e| e.to_string())?;
        prop_assert!(ci == ri, "ECQ index mismatch (levels={levels})");
        prop_assert!(ci == r4i, "ECQ rans4 index mismatch (levels={levels})");
        prop_assert!(
            rh.recon.as_ref() == Some(&d.quantizer.recon),
            "rANS ECQ header lost the recon table"
        );
        prop_assert!(
            r4h.recon.as_ref() == Some(&d.quantizer.recon),
            "rans4 ECQ header lost the recon table"
        );
        Ok(())
    });
}

#[test]
fn corrupt_or_truncated_rans_streams_error_not_panic() {
    prop_check("diff_rans_corruption", 60, |g: &mut Gen| {
        let n = g.usize_in(16, 4_000);
        let levels = *g.choice(&[2usize, 3, 4, 8]);
        let entropy = *g.choice(&[EntropyKind::Rans, EntropyKind::Rans4]);
        let xs = g.activation_vec(n, 0.5);
        let mut codec = session(uniform(levels, 2.0), entropy, n);
        let bytes = codec.encode(&xs).bytes;

        // Any truncation of the payload region is a guaranteed error: the
        // decoder consumes exactly the bytes the encoder emitted, so a
        // shorter stream either starves renormalization or fails the
        // final-state / consumption checks.
        let cut = g.usize_in(12, bytes.len() - 1);
        prop_assert!(
            codec.decode(&bytes[..cut]).is_err(),
            "{entropy} truncation to {cut}/{} accepted (n={n} levels={levels})",
            bytes.len()
        );

        // A corrupted byte anywhere must never panic; it either errors
        // (the common case — table validation, state bound, final-state
        // check) or, for a flip the checks cannot see (e.g. the table
        // entry of a bit position the data never uses), decodes to the
        // same in-range shape.
        let i = g.usize_in(12, bytes.len() - 1);
        let mut bad = bytes.clone();
        bad[i] ^= (g.u64() as u8) | 1;
        if let Ok(decoded) = codec.decode(&bad) {
            let header = decoded.info.header.as_ref().expect("ok decode has header");
            prop_assert!(decoded.values.len() == n, "corrupt decode changed length");
            for &v in &decoded.values {
                prop_assert!(
                    v >= header.c_min && v <= header.c_max,
                    "corrupt decode out of range: {v}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn rans_initial_state_corruption_is_always_detected() {
    // The bytes after the frequency table are the decoder's initial
    // states — 8 for the 2-way backend, 16 for the 4-way one; flipping
    // any of them derails the state walk, and landing back on exactly
    // `[RANS_LOWER; WAYS]` afterwards is a vanishing accident —
    // deterministic inputs make this assertion stable.
    for (entropy, state_bytes) in [(EntropyKind::Rans, 8), (EntropyKind::Rans4, 16)] {
        let mut g = Gen::new("rans_state_corruption", 0);
        let xs = g.activation_vec(2_048, 0.5);
        let mut codec = session(uniform(4, 2.0), entropy, xs.len());
        let bytes = codec.encode(&xs).bytes;
        let state_off = 12 + 2 * 3; // header + 3-position table
        for i in state_off..state_off + state_bytes {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut bad = bytes.clone();
                bad[i] ^= flip;
                assert!(
                    codec.decode(&bad).is_err(),
                    "{entropy} state byte {i} flipped by {flip:#04x} went undetected"
                );
            }
        }
    }
}

#[test]
fn batched_containers_are_differential_too() {
    prop_check("diff_batched", 15, |g: &mut Gen| {
        let n = g.usize_in(0, 30_000);
        let tile = g.usize_in(64, 4_096);
        let levels = *g.choice(&[2usize, 3, 4, 8]);
        let xs = g.activation_vec(n, 0.5);
        let threads = g.usize_in(1, 4);
        let batched = |entropy: EntropyKind| {
            CodecBuilder::new(uniform(levels, 2.0))
                .image_size(32)
                .entropy(entropy)
                .threads(threads)
                .tile_elems(tile)
                .force_container()
                .build()
        };
        let mut cc = batched(EntropyKind::Cabac);
        let mut rc = batched(EntropyKind::Rans);
        let mut r4c = batched(EntropyKind::Rans4);
        let cb = cc.encode(&xs);
        let rb = rc.encode(&xs);
        let r4b = r4c.encode(&xs);
        let cd = cc.decode(&cb.bytes).map_err(|e| e.to_string())?;
        let rd = rc.decode(&rb.bytes).map_err(|e| e.to_string())?;
        let r4d = r4c.decode(&r4b.bytes).map_err(|e| e.to_string())?;
        prop_assert!(cd.values == rd.values, "batched reconstruction mismatch (n={n} tile={tile})");
        prop_assert!(
            cd.values == r4d.values,
            "batched rans4 reconstruction mismatch (n={n} tile={tile})"
        );
        let (ch, rh) = (
            cd.info.header.as_ref().ok_or("cabac header")?,
            rd.info.header.as_ref().ok_or("rans header")?,
        );
        prop_assert!(
            ch.entropy == EntropyKind::Cabac && rh.entropy == EntropyKind::Rans,
            "headers"
        );
        prop_assert!(
            r4d.info.header.as_ref().ok_or("rans4 header")?.entropy == EntropyKind::Rans4,
            "rans4 header"
        );
        // Containers advertise their backend without decoding a tile —
        // through the one consolidated sniffer.
        prop_assert!(
            lwfc::sniff(&rb.bytes).entropy == Some(EntropyKind::Rans),
            "container sniff"
        );
        prop_assert!(
            lwfc::sniff(&r4b.bytes).entropy == Some(EntropyKind::Rans4),
            "rans4 container sniff"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Serving-path acceptance: rANS over a real TCP transport

mod tcp_path {
    use std::time::Duration;

    use anyhow::Result;
    use lwfc::codec::EntropyKind;
    use lwfc::coordinator::{
        run_pipeline, CloudStage, CompressedItem, EdgeStage, Outcome, PipelineConfig, Request,
        TaskKind, TcpTransport, Transport,
    };
    use lwfc::util::prop::Gen;
    use lwfc::{Codec, CodecBuilder, QuantSpec};

    const ELEMS: usize = 2_048;
    const TILE: usize = 512;

    fn codec_for(entropy: EntropyKind) -> Codec {
        CodecBuilder::new(QuantSpec::Uniform {
            c_min: 0.0,
            c_max: 2.0,
            levels: 4,
        })
        .image_size(32)
        .entropy(entropy)
        .threads(2)
        .tile_elems(TILE)
        .force_container()
        .expect_elements(ELEMS)
        .build()
    }

    fn tensor_for(image_index: u64) -> Vec<f32> {
        Gen::new("entropy_tcp", image_index).activation_vec(ELEMS, 0.5)
    }

    /// Which backend a given request uses: the fleet rotates through all
    /// three, so one wire carries a mix of every header id.
    fn backend_for(image_index: u64) -> EntropyKind {
        match image_index % 3 {
            0 => EntropyKind::Rans,
            1 => EntropyKind::Cabac,
            _ => EntropyKind::Rans4,
        }
    }

    /// Edge stage rotating requests across the backends — one device
    /// fleet, mixed backends, one wire.
    struct MixedEdge {
        cabac: Codec,
        rans: Codec,
        rans4: Codec,
    }

    impl EdgeStage for MixedEdge {
        fn process(&mut self, requests: &[Request]) -> Result<Vec<CompressedItem>> {
            let mut out = Vec::with_capacity(requests.len());
            for r in requests {
                let codec = match backend_for(r.image_index) {
                    EntropyKind::Rans => &mut self.rans,
                    EntropyKind::Cabac => &mut self.cabac,
                    EntropyKind::Rans4 => &mut self.rans4,
                };
                let xs = tensor_for(r.image_index);
                let s = codec.encode(&xs);
                out.push(CompressedItem {
                    id: r.id,
                    image_index: r.image_index,
                    bytes: s.bytes,
                    elements: s.elements,
                    arrived: r.arrived,
                    encoded: std::time::Instant::now(),
                });
            }
            Ok(out)
        }
    }

    /// Cloud stage verifying the reconstruction against the regenerated
    /// tensor and the header against the expected per-item backend.
    struct VerifyCloud {
        codec: Codec,
        scratch: Vec<f32>,
    }

    impl CloudStage for VerifyCloud {
        fn process(&mut self, items: &[CompressedItem]) -> Result<Vec<Outcome>> {
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                let info = self.codec.decode_into(&item.bytes, &mut self.scratch)?;
                let want = backend_for(item.image_index);
                let q = codec_for(want).quant_spec().materialize();
                let expect: Vec<f32> =
                    tensor_for(item.image_index).iter().map(|&x| q.fake_quant(x)).collect();
                out.push(Outcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(info.entropy == Some(want) && self.scratch == expect),
                    detections: Vec::new(),
                    latency_s: item.arrived.elapsed().as_secs_f64(),
                    bits_per_element: item.bits_per_element(),
                });
            }
            Ok(out)
        }
    }

    fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = tx.send(f());
        });
        match rx.recv_timeout(Duration::from_secs(secs)) {
            Ok(v) => v,
            Err(_) => panic!("timed out after {secs}s — the pipeline hung"),
        }
    }

    #[test]
    fn mixed_backend_streams_roundtrip_over_tcp() {
        with_timeout(120, || {
            let n = 24;
            let transport = TcpTransport::loopback(TaskKind::ClassifyAlex, 8, 64).unwrap();
            let out = run_pipeline(
                &PipelineConfig {
                    edge_workers: 2,
                    requests: n,
                    batch: 4,
                    queue_capacity: 8,
                    first_index: 0,
                },
                &transport,
                |_w| {
                    Ok(MixedEdge {
                        cabac: codec_for(EntropyKind::Cabac),
                        rans: codec_for(EntropyKind::Rans),
                        rans4: codec_for(EntropyKind::Rans4),
                    })
                },
                || {
                    Ok(VerifyCloud {
                        codec: codec_for(EntropyKind::Cabac),
                        scratch: Vec::new(),
                    })
                },
            )
            .unwrap();
            assert_eq!(out.outcomes.len(), n);
            for o in &out.outcomes {
                assert_eq!(
                    o.correct,
                    Some(true),
                    "request {} failed wire round-trip verification",
                    o.id
                );
            }
            let stats = transport.stats();
            assert_eq!(stats.items, n as u64);
            assert!(stats.bytes_sent > 0 && stats.bytes_received > 0);
        });
    }
}

//! Integration tests for the concurrency substrate the batched codec and
//! the coordinator pipeline run on: `ThreadPool::map_indexed` ordering,
//! `fold_indexed` merge correctness, and `BoundedQueue` behaviour under
//! producer/consumer contention.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use lwfc::util::threadpool::{BoundedQueue, ThreadPool};

#[test]
fn map_indexed_preserves_order_under_uneven_work() {
    // Items deliberately take wildly different times; results must still
    // land at their own index.
    let pool = ThreadPool::new(8);
    let out = pool.map_indexed(200, |i| {
        if i % 7 == 0 {
            thread::sleep(Duration::from_micros(200));
        }
        i * 3 + 1
    });
    assert_eq!(out, (0..200).map(|i| i * 3 + 1).collect::<Vec<_>>());
}

#[test]
fn map_indexed_visits_every_index_exactly_once() {
    let pool = ThreadPool::new(4);
    let hits: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
    let _ = pool.map_indexed(500, |i| hits[i].fetch_add(1, Ordering::SeqCst));
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} visited wrong count");
    }
}

#[test]
fn map_indexed_edge_sizes() {
    let pool = ThreadPool::new(4);
    assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
    assert_eq!(pool.map_indexed(1, |i| i + 9), vec![9]);
    // More workers than items.
    assert_eq!(ThreadPool::new(16).map_indexed(3, |i| i), vec![0, 1, 2]);
    // Degenerate pool size clamps to 1 worker.
    assert_eq!(ThreadPool::new(0).workers(), 1);
}

#[test]
fn fold_indexed_matches_serial_reduction() {
    let pool = ThreadPool::new(5);
    let total = pool.fold_indexed(
        10_000,
        || 0u64,
        |acc, i| *acc += (i as u64) * (i as u64),
        |a, b| a + b,
    );
    let serial: u64 = (0..10_000u64).map(|i| i * i).sum();
    assert_eq!(total, serial);
}

#[test]
fn fold_indexed_merge_handles_nontrivial_accumulators() {
    // (count, min, max) accumulator — merge must combine partial windows
    // correctly, the same shape the Welford merge in the coordinator uses.
    let pool = ThreadPool::new(3);
    let (count, min, max) = pool.fold_indexed(
        777,
        || (0usize, usize::MAX, 0usize),
        |acc, i| {
            acc.0 += 1;
            acc.1 = acc.1.min(i);
            acc.2 = acc.2.max(i);
        },
        |a, b| (a.0 + b.0, a.1.min(b.1), a.2.max(b.2)),
    );
    assert_eq!((count, min, max), (777, 0, 776));
}

#[test]
fn fold_indexed_empty_returns_init() {
    let pool = ThreadPool::new(4);
    assert_eq!(pool.fold_indexed(0, || 41u32, |_, _| {}, |a, _| a), 41);
}

#[test]
fn queue_mpmc_contention_delivers_every_item_once() {
    const PRODUCERS: usize = 4;
    const CONSUMERS: usize = 3;
    const PER_PRODUCER: usize = 2_000;

    let q: BoundedQueue<usize> = BoundedQueue::new(8); // tight: forces blocking
    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = q.clone();
        handles.push(thread::spawn(move || {
            for i in 0..PER_PRODUCER {
                q.push(p * PER_PRODUCER + i).unwrap();
            }
        }));
    }
    let seen = Arc::new(
        (0..PRODUCERS * PER_PRODUCER)
            .map(|_| AtomicUsize::new(0))
            .collect::<Vec<_>>(),
    );
    let mut consumers = Vec::new();
    for _ in 0..CONSUMERS {
        let q = q.clone();
        let seen = Arc::clone(&seen);
        consumers.push(thread::spawn(move || {
            let mut got = 0usize;
            while let Some(v) = q.pop() {
                seen[v].fetch_add(1, Ordering::SeqCst);
                got += 1;
            }
            got
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    q.close();
    let total: usize = consumers.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, PRODUCERS * PER_PRODUCER);
    for (v, s) in seen.iter().enumerate() {
        assert_eq!(s.load(Ordering::SeqCst), 1, "item {v} delivered wrong count");
    }
}

#[test]
fn queue_capacity_is_respected_under_pressure() {
    let q: BoundedQueue<u32> = BoundedQueue::new(4);
    let q2 = q.clone();
    let producer = thread::spawn(move || {
        for i in 0..1_000 {
            q2.push(i).unwrap();
        }
        q2.close();
    });
    let mut count = 0;
    while let Some(_v) = q.pop() {
        // Sampled invariant: the queue never holds more than its capacity.
        assert!(q.len() <= 4, "queue over capacity: {}", q.len());
        count += 1;
    }
    producer.join().unwrap();
    assert_eq!(count, 1_000);
}

#[test]
fn close_unblocks_producers_and_consumers() {
    // Blocked producer gets its item back on close.
    let q: BoundedQueue<u32> = BoundedQueue::new(1);
    q.push(1).unwrap();
    let q2 = q.clone();
    let blocked_push = thread::spawn(move || q2.push(2));
    thread::sleep(Duration::from_millis(20));
    q.close();
    assert_eq!(blocked_push.join().unwrap(), Err(2));

    // Blocked consumer wakes with None once closed and drained.
    let q: BoundedQueue<u32> = BoundedQueue::new(1);
    let q2 = q.clone();
    let blocked_pop = thread::spawn(move || q2.pop());
    thread::sleep(Duration::from_millis(20));
    q.close();
    assert_eq!(blocked_pop.join().unwrap(), None);

    // Push after close is rejected.
    assert_eq!(q.push(7), Err(7));
}

#[test]
fn pop_up_to_batches_under_contention() {
    let q: BoundedQueue<usize> = BoundedQueue::new(64);
    let q2 = q.clone();
    let producer = thread::spawn(move || {
        for i in 0..5_000 {
            q2.push(i).unwrap();
        }
        q2.close();
    });
    let mut got = Vec::new();
    while let Some(mut batch) = q.pop_up_to(17) {
        assert!(!batch.is_empty() && batch.len() <= 17);
        got.append(&mut batch);
    }
    producer.join().unwrap();
    // Single consumer: FIFO order is preserved across batches.
    assert_eq!(got, (0..5_000).collect::<Vec<_>>());
}

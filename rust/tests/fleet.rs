//! Fleet-scale integration tests for the event-driven cloud daemon: many
//! concurrent edge clients against one readiness loop, with synthetic
//! codec-only stages so everything runs without artifacts or the `xla`
//! feature.
//!
//! * a fleet of `LWFC_FLEET_EDGES` (default 256) concurrent edges is
//!   served with **zero** refusals below the admission quota, and the
//!   wire payloads match the in-process loopback pipeline byte-for-byte;
//! * connections beyond `max_conns` are shed with a BUSY frame — the
//!   client backs off and retries without spending reconnect budget,
//!   instead of dying on an unexplained EOF;
//! * `shutdown()` under live streaming load drains within a watchdog
//!   bound (the old implementation dialed its own listener to unblock
//!   `accept`, which hangs on some bind addresses);
//! * an idle daemon shuts down instantly, and dropping one without
//!   calling `shutdown()` neither hangs nor double-joins;
//! * handler failures surface through `take_error()` and the final
//!   report instead of vanishing with the connection.

use std::collections::HashMap;
use std::sync::{Arc, Barrier, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};
use lwfc::codec::DecodeCache;
use lwfc::coordinator::{
    run_pipeline, ClientStats, CloudDaemon, CloudStage, CompressedItem, DaemonConfig, EdgeClient,
    EdgeStage, LoopbackTransport, Outcome, PipelineConfig, Request, RetryPolicy, TaskKind,
    WireItem, WireOutcome,
};
use lwfc::util::prop::Gen;
use lwfc::util::timer::Percentiles;
use lwfc::{Codec, CodecBuilder, QuantSpec};

const ELEMS: usize = 512;
const TILE: usize = 256;
const TASK: TaskKind = TaskKind::ClassifyAlex;

type PayloadMap = Arc<Mutex<HashMap<u64, Vec<u8>>>>;

/// Fleet width, overridable so CI smoke runs can stay light
/// (`LWFC_FLEET_EDGES=64`) while the default exercises ≥256 edges.
fn fleet_edges() -> usize {
    env_usize("LWFC_FLEET_EDGES", 256)
}

/// Items each edge sends in the fleet test (`LWFC_FLEET_ITEMS`).
fn fleet_items() -> usize {
    env_usize("LWFC_FLEET_ITEMS", 2)
}

/// Performance floor for the fleet run: aggregate throughput in requests
/// per second, from fleet launch (dial + barrier included) to the last
/// outcome joined. The default is
/// deliberately loose (any working daemon clears it by an order of
/// magnitude); CI's fleet-smoke pins a tighter value via
/// `LWFC_FLEET_MIN_RPS` so real regressions fail the gate.
fn fleet_min_rps() -> f64 {
    env_usize("LWFC_FLEET_MIN_RPS", 25) as f64
}

/// Performance ceiling for the fleet run: p99 send→outcome round-trip in
/// milliseconds over the merged per-client trackers. Loose default,
/// tightened in CI via `LWFC_FLEET_MAX_P99_MS`.
fn fleet_max_p99_ms() -> f64 {
    env_usize("LWFC_FLEET_MAX_P99_MS", 5000) as f64
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Every party runs the same `Codec` session config, so client-side and
/// pipeline-side bytes are identical by construction.
fn session() -> Codec {
    CodecBuilder::new(QuantSpec::Uniform {
        c_min: 0.0,
        c_max: 2.0,
        levels: 4,
    })
    .image_size(32)
    .threads(1)
    .tile_elems(TILE)
    .force_container()
    .expect_elements(ELEMS)
    .build()
}

/// The deterministic "sensor capture" both sides regenerate from the
/// corpus index.
fn tensor_for(image_index: u64) -> Vec<f32> {
    Gen::new("fleet", image_index).activation_vec(ELEMS, 0.5)
}

fn encode_item(image_index: u64, codec: &mut Codec) -> (Vec<u8>, usize) {
    let xs = tensor_for(image_index);
    let s = codec.encode(&xs);
    (s.bytes, s.elements)
}

/// Decode + verify one item; `Some(true)` iff the reconstruction equals
/// the fake-quantized source tensor.
fn verify_item(bytes: &[u8], elements: usize, image_index: u64, codec: &mut Codec) -> Result<bool> {
    let decoded = codec.decode(bytes)?;
    let q = codec.quant_spec().materialize();
    let expect: Vec<f32> = tensor_for(image_index).iter().map(|&x| q.fake_quant(x)).collect();
    Ok(elements == decoded.values.len() && decoded.values == expect)
}

/// Watchdog: a daemon-hang regression turns into a test failure, not a
/// stuck test runner.
fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(_) => panic!("timed out after {secs}s — the daemon hung instead of terminating"),
    }
}

// ---------------------------------------------------------------------------
// Loopback reference pipeline (no sockets)

struct FleetEdge {
    codec: Codec,
}

impl EdgeStage for FleetEdge {
    fn process(&mut self, requests: &[Request]) -> Result<Vec<CompressedItem>> {
        let mut out = Vec::with_capacity(requests.len());
        for r in requests {
            let (bytes, elements) = encode_item(r.image_index, &mut self.codec);
            out.push(CompressedItem {
                id: r.id,
                image_index: r.image_index,
                bytes,
                elements,
                arrived: r.arrived,
                encoded: Instant::now(),
            });
        }
        Ok(out)
    }
}

struct FleetCloud {
    codec: Codec,
    seen: PayloadMap,
}

impl CloudStage for FleetCloud {
    fn process(&mut self, items: &[CompressedItem]) -> Result<Vec<Outcome>> {
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            self.seen.lock().unwrap().insert(item.image_index, item.bytes.clone());
            let correct =
                verify_item(&item.bytes, item.elements, item.image_index, &mut self.codec)?;
            out.push(Outcome {
                id: item.id,
                image_index: item.image_index,
                correct: Some(correct),
                detections: Vec::new(),
                latency_s: item.arrived.elapsed().as_secs_f64(),
                bits_per_element: item.bits_per_element(),
            });
        }
        Ok(out)
    }
}

/// Run the corpus range `0..requests` through the in-process loopback
/// pipeline, recording exactly what the cloud stage received.
fn run_reference(requests: usize) -> (Vec<Outcome>, PayloadMap) {
    let seen: PayloadMap = Arc::new(Mutex::new(HashMap::new()));
    let cloud_seen = Arc::clone(&seen);
    let loopback = LoopbackTransport::new(8, 64);
    let out = run_pipeline(
        &PipelineConfig {
            edge_workers: 2,
            requests,
            batch: 4,
            queue_capacity: 8,
            first_index: 0,
        },
        &loopback,
        |_w| Ok(FleetEdge { codec: session() }),
        move || {
            Ok(FleetCloud {
                codec: session(),
                seen: Arc::clone(&cloud_seen),
            })
        },
    )
    .expect("loopback reference pipeline failed");
    (out.outcomes, seen)
}

/// A junk item for tests that exercise daemon plumbing without a codec:
/// the handler in those tests never decodes the payload.
fn junk_item(id: u64) -> WireItem {
    WireItem {
        id,
        image_index: id,
        elements: 64,
        bytes: vec![0x5A; 64],
    }
}

// ---------------------------------------------------------------------------
// Tests

/// Tentpole acceptance: a fleet of ≥256 concurrent edges (the old
/// thread-per-connection daemon refused everything past `conns`) is fully
/// served with zero sheds, zero reconnects, and wire payloads that match
/// the loopback transport byte-for-byte.
#[test]
fn fleet_of_edges_is_served_without_refusals_below_quota() {
    with_timeout(300, || {
        let edges = fleet_edges();
        let items = fleet_items();
        let total = edges * items;

        let (ref_outcomes, ref_seen) = run_reference(total);
        assert_eq!(ref_outcomes.len(), total);

        let daemon_seen: PayloadMap = Arc::new(Mutex::new(HashMap::new()));
        let handler_seen = Arc::clone(&daemon_seen);
        let config = DaemonConfig {
            decode_workers: 4,
            max_conns: edges + 8, // fleet fits: nothing may be shed
            max_inflight: 2,
            busy_retry_ms: 5,
        };
        let daemon = CloudDaemon::start_with("127.0.0.1:0", TASK, config, move |_conn| {
            let mut codec = session();
            let seen = Arc::clone(&handler_seen);
            Ok(move |item: WireItem| -> Result<WireOutcome> {
                seen.lock().unwrap().insert(item.image_index, item.bytes.clone());
                let correct =
                    verify_item(&item.bytes, item.elements as usize, item.image_index, &mut codec)?;
                Ok(WireOutcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(correct),
                    latency_s: 0.0,
                    bits_per_element: 0.0,
                    detections: Vec::new(),
                })
            })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        // Everyone connects first, then the barrier releases all sends at
        // once — the daemon holds the whole fleet open concurrently.
        let barrier = Arc::new(Barrier::new(edges));
        let mut joins = Vec::new();
        for c in 0..edges {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            joins.push(thread::spawn(move || -> Result<(ClientStats, Vec<WireOutcome>)> {
                let mut codec = session();
                let mut client = EdgeClient::connect(&addr, TASK, 2, RetryPolicy::default())?;
                barrier.wait();
                let mut got = Vec::new();
                for k in 0..items {
                    let image_index = (c * items + k) as u64;
                    let (bytes, elements) = encode_item(image_index, &mut codec);
                    got.extend(client.send(WireItem {
                        id: image_index,
                        image_index,
                        elements: elements as u64,
                        bytes,
                    })?);
                }
                let (rest, stats) = client.finish()?;
                got.extend(rest);
                Ok((stats, got))
            }));
        }

        let t0 = Instant::now();
        let mut all: Vec<WireOutcome> = Vec::new();
        let mut rtt = Percentiles::default();
        for j in joins {
            let (stats, got) = j.join().expect("client thread panicked").expect("client failed");
            assert_eq!(stats.outcomes_received, items as u64);
            assert_eq!(stats.busy_shed, 0, "shed below quota: {stats:?}");
            assert_eq!(stats.reconnects, 0, "refusal below quota: {stats:?}");
            rtt.merge(&stats.rtt);
            all.extend(got);
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let report = daemon.shutdown();

        all.sort_by_key(|o| o.id);
        assert_eq!(all.len(), total);
        for (k, o) in all.iter().enumerate() {
            assert_eq!(o.id, k as u64);
            assert_eq!(o.correct, Some(true), "request {k} failed verification");
        }
        assert_eq!(rtt.len(), total);
        assert_eq!(report.connections, edges as u64, "report: {report:?}");
        assert_eq!(report.shed, 0, "report: {report:?}");
        assert_eq!(report.items, total as u64);
        assert!(report.bytes_in > 0 && report.bytes_out > 0);
        assert!(report.errors.is_empty(), "daemon errors: {:?}", report.errors);

        // Performance gates: aggregate throughput floor and merged-p99
        // RTT ceiling (thresholds env-overridable; CI pins tight values).
        let rps = total as f64 / wall_s.max(1e-9);
        let p99_ms = rtt.quantile(0.99) * 1e3;
        assert!(
            rps >= fleet_min_rps(),
            "fleet throughput regressed: {rps:.1} req/s < {} req/s floor \
             ({total} requests in {wall_s:.2}s)",
            fleet_min_rps()
        );
        assert!(
            p99_ms <= fleet_max_p99_ms(),
            "fleet p99 RTT regressed: {p99_ms:.1}ms > {}ms ceiling \
             ({} samples)",
            fleet_max_p99_ms(),
            rtt.len()
        );

        // What crossed the real TCP wire is byte-for-byte what crossed
        // the in-process loopback queue.
        let daemon_map = daemon_seen.lock().unwrap();
        let ref_map = ref_seen.lock().unwrap();
        assert_eq!(daemon_map.len(), total);
        assert_eq!(
            *daemon_map, *ref_map,
            "TCP wire payloads diverged from the loopback transport"
        );
    });
}

/// Cache-enabled fleet variant (CI's fleet-smoke runs this again with
/// `LWFC_FLEET_DECODE_CACHE_MB=64` to size the budget): every edge
/// streams the **same** small corpus, so the shared content-addressed
/// decode cache must turn the overlap into hits — under the same
/// throughput floor and p99 ceiling as the plain fleet run — while every
/// outcome still verifies bit-exact against `fake_quant`.
#[test]
fn fleet_with_shared_decode_cache_hits_on_overlapping_content() {
    with_timeout(300, || {
        let edges = fleet_edges();
        let items = fleet_items().max(2);
        let total = edges * items;
        let budget_mb = env_usize("LWFC_FLEET_DECODE_CACHE_MB", 64);
        let cache = Arc::new(DecodeCache::new(budget_mb << 20));

        // One tenant's fleet: every daemon connection shares the cache
        // under the same (default) salt, so edges hit on each other's
        // content, not just their own repeats.
        let handler_cache = Arc::clone(&cache);
        let config = DaemonConfig {
            decode_workers: 4,
            max_conns: edges + 8,
            max_inflight: 2,
            busy_retry_ms: 5,
        };
        let daemon = CloudDaemon::start_with("127.0.0.1:0", TASK, config, move |_conn| {
            let mut codec = CodecBuilder::new(QuantSpec::Uniform {
                c_min: 0.0,
                c_max: 2.0,
                levels: 4,
            })
            .image_size(32)
            .threads(1)
            .tile_elems(TILE)
            .force_container()
            .expect_elements(ELEMS)
            .decode_cache_shared(Arc::clone(&handler_cache))
            .build();
            Ok(move |item: WireItem| -> Result<WireOutcome> {
                let correct =
                    verify_item(&item.bytes, item.elements as usize, item.image_index, &mut codec)?;
                Ok(WireOutcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(correct),
                    latency_s: 0.0,
                    bits_per_element: 0.0,
                    detections: Vec::new(),
                })
            })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        let barrier = Arc::new(Barrier::new(edges));
        let mut joins = Vec::new();
        for _c in 0..edges {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            joins.push(thread::spawn(move || -> Result<(ClientStats, Vec<WireOutcome>)> {
                let mut codec = session();
                let mut client = EdgeClient::connect(&addr, TASK, 2, RetryPolicy::default())?;
                barrier.wait();
                let mut got = Vec::new();
                for k in 0..items {
                    // The shared corpus: every edge sends the same images.
                    let image_index = k as u64;
                    let (bytes, elements) = encode_item(image_index, &mut codec);
                    got.extend(client.send(WireItem {
                        id: k as u64,
                        image_index,
                        elements: elements as u64,
                        bytes,
                    })?);
                }
                let (rest, stats) = client.finish()?;
                got.extend(rest);
                Ok((stats, got))
            }));
        }

        let t0 = Instant::now();
        let mut rtt = Percentiles::default();
        for j in joins {
            let (stats, got) = j.join().expect("client thread panicked").expect("client failed");
            assert_eq!(stats.outcomes_received, items as u64);
            assert_eq!(stats.busy_shed, 0, "shed below quota: {stats:?}");
            assert_eq!(stats.reconnects, 0, "refusal below quota: {stats:?}");
            rtt.merge(&stats.rtt);
            for o in &got {
                assert_eq!(o.correct, Some(true), "cached decode broke item {}", o.id);
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let report = daemon.shutdown();
        assert_eq!(report.items, total as u64);
        assert!(report.errors.is_empty(), "daemon errors: {:?}", report.errors);

        // Same gates as the plain fleet run: the cache must not cost
        // throughput or tail latency.
        let rps = total as f64 / wall_s.max(1e-9);
        let p99_ms = rtt.quantile(0.99) * 1e3;
        assert!(
            rps >= fleet_min_rps(),
            "cached fleet throughput regressed: {rps:.1} req/s < {} req/s floor",
            fleet_min_rps()
        );
        assert!(
            p99_ms <= fleet_max_p99_ms(),
            "cached fleet p99 RTT regressed: {p99_ms:.1}ms > {}ms ceiling",
            fleet_max_p99_ms()
        );

        // The overlap materialized as cache hits (only the first decode
        // of each distinct image — plus rare concurrent-miss races —
        // touches the entropy decoder), inside the byte budget.
        let stats = cache.stats();
        assert!(
            stats.hits > 0,
            "overlapping fleet content produced no cache hits: {stats:?}"
        );
        assert!(stats.bytes_saved > 0);
        assert!(cache.resident_bytes() <= cache.budget_bytes());
    });
}

/// Over-quota connections get a BUSY frame and a graceful close — the
/// client backs off and redials without spending its reconnect budget,
/// and every item still completes.
#[test]
fn over_quota_edges_are_shed_with_busy_not_eof() {
    with_timeout(120, || {
        let edges = 12usize;
        let items = 4u64;
        let config = DaemonConfig {
            decode_workers: 2,
            max_conns: 2, // far below the fleet: most connections shed
            max_inflight: 2,
            busy_retry_ms: 5,
        };
        let daemon = CloudDaemon::start_with("127.0.0.1:0", TASK, config, move |_conn| {
            let mut codec = session();
            Ok(move |item: WireItem| -> Result<WireOutcome> {
                // Hold the slot long enough that the quota stays
                // contended while the rest of the fleet dials in.
                thread::sleep(Duration::from_millis(2));
                let correct =
                    verify_item(&item.bytes, item.elements as usize, item.image_index, &mut codec)?;
                Ok(WireOutcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(correct),
                    latency_s: 0.0,
                    bits_per_element: 0.0,
                    detections: Vec::new(),
                })
            })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        // Everyone dials at once, straight into a 2-connection quota.
        let barrier = Arc::new(Barrier::new(edges));
        let mut joins = Vec::new();
        for c in 0..edges {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            joins.push(thread::spawn(move || -> Result<(ClientStats, Vec<WireOutcome>)> {
                let retry = RetryPolicy {
                    attempts: 5,
                    backoff: Duration::from_millis(2),
                    max_reconnects: 4,
                    ..RetryPolicy::default()
                };
                barrier.wait();
                let mut codec = session();
                let mut client = EdgeClient::connect(&addr, TASK, 1, retry)?;
                let mut got = Vec::new();
                for k in 0..items {
                    let image_index = c as u64 * items + k;
                    let (bytes, elements) = encode_item(image_index, &mut codec);
                    got.extend(client.send(WireItem {
                        id: image_index,
                        image_index,
                        elements: elements as u64,
                        bytes,
                    })?);
                }
                let (rest, stats) = client.finish()?;
                got.extend(rest);
                Ok((stats, got))
            }));
        }

        let mut all: Vec<WireOutcome> = Vec::new();
        let mut total_shed = 0u64;
        for j in joins {
            let (stats, got) = j.join().expect("client thread panicked").expect("client failed");
            assert_eq!(stats.outcomes_received, items);
            // Shed is flow control: it must never consume the reconnect
            // budget (the bug this PR fixes burned it on a full daemon).
            assert_eq!(stats.reconnects, 0, "shed spent reconnect budget: {stats:?}");
            total_shed += stats.busy_shed;
            all.extend(got);
        }
        let report = daemon.shutdown();

        all.sort_by_key(|o| o.id);
        assert_eq!(all.len(), edges * items as usize);
        for o in &all {
            assert_eq!(o.correct, Some(true));
        }
        assert!(total_shed >= 1, "quota never triggered a BUSY shed");
        assert!(report.shed >= 1, "report: {report:?}");
        assert_eq!(report.items, (edges as u64) * items);
        assert!(report.errors.is_empty(), "daemon errors: {:?}", report.errors);
        // Every edge was eventually admitted (some after shed redials).
        assert!(report.connections >= edges as u64, "report: {report:?}");
    });
}

/// `shutdown()` while a fleet is actively streaming drains in bounded
/// time: in-flight decodes are answered, connections half-close, and the
/// loop thread joins — no self-dial, no hang, no orphaned clients.
#[test]
fn shutdown_under_load_drains_within_bound() {
    with_timeout(60, || {
        let config = DaemonConfig {
            decode_workers: 2,
            max_conns: 64,
            max_inflight: 4,
            busy_retry_ms: 5,
        };
        let daemon = CloudDaemon::start_with("127.0.0.1:0", TASK, config, |_conn| {
            Ok(move |item: WireItem| -> Result<WireOutcome> {
                Ok(WireOutcome {
                    id: item.id,
                    image_index: item.image_index,
                    correct: Some(true),
                    latency_s: 0.0,
                    bits_per_element: 0.0,
                    detections: Vec::new(),
                })
            })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        // Streamers send until the daemon goes away, then give up fast.
        let mut joins = Vec::new();
        for _t in 0..6 {
            let addr = addr.clone();
            joins.push(thread::spawn(move || -> u64 {
                let retry = RetryPolicy {
                    attempts: 2,
                    backoff: Duration::from_millis(2),
                    max_reconnects: 2,
                    ..RetryPolicy::default()
                };
                let Ok(mut client) = EdgeClient::connect(&addr, TASK, 2, retry) else {
                    return 0;
                };
                let mut sent = 0u64;
                for id in 0..u64::MAX {
                    if client.send(junk_item(id)).is_err() {
                        break;
                    }
                    sent += 1;
                }
                sent
            }));
        }

        thread::sleep(Duration::from_millis(300));
        let t0 = Instant::now();
        let report = daemon.shutdown();
        let drain = t0.elapsed();
        assert!(
            drain < Duration::from_secs(30),
            "shutdown under load took {drain:?}"
        );
        assert!(report.items > 0, "daemon served nothing before shutdown");

        // Every streamer must terminate once the listener is gone — a
        // hang here is caught by the watchdog.
        let total_sent: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert!(total_sent > 0);
    });
}

/// An idle daemon shuts down immediately (the waker replaces the old
/// connect-to-own-listener drain hack, which counted a phantom
/// connection and hung on unroutable bind addresses), and dropping a
/// daemon without `shutdown()` neither hangs nor double-joins.
#[test]
fn idle_shutdown_is_instant_and_drop_is_safe() {
    with_timeout(20, || {
        let daemon = CloudDaemon::start("127.0.0.1:0", TASK, 2, |_conn| {
            Ok(move |_item: WireItem| -> Result<WireOutcome> { Err(anyhow!("unused")) })
        })
        .unwrap();
        assert!(daemon.take_error().is_none());
        let stats = daemon.stats();
        assert_eq!(stats.active_conns, 0);
        let report = daemon.shutdown();
        assert_eq!(report.connections, 0, "shutdown dialed its own listener");
        assert_eq!(report.items, 0);
        assert!(report.errors.is_empty(), "daemon errors: {:?}", report.errors);

        // Drop without shutdown: the Drop impl drains idempotently.
        let daemon = CloudDaemon::start("127.0.0.1:0", TASK, 2, |_conn| {
            Ok(move |_item: WireItem| -> Result<WireOutcome> { Err(anyhow!("unused")) })
        })
        .unwrap();
        drop(daemon);
    });
}

/// Handler failures are recorded and surfaced through `take_error()` and
/// the shutdown report; the failing connection is torn down gracefully
/// while the daemon keeps running.
#[test]
fn handler_errors_surface_via_take_error_and_report() {
    with_timeout(60, || {
        let daemon = CloudDaemon::start("127.0.0.1:0", TASK, 2, |_conn| {
            Ok(move |_item: WireItem| -> Result<WireOutcome> { Err(anyhow!("boom")) })
        })
        .unwrap();
        let addr = daemon.local_addr().to_string();

        let retry = RetryPolicy {
            attempts: 2,
            backoff: Duration::from_millis(2),
            max_reconnects: 1,
            ..RetryPolicy::default()
        };
        let mut client = EdgeClient::connect(&addr, TASK, 4, retry).unwrap();
        let send_result = client.send(junk_item(0));
        let finish_result = send_result.and_then(|_| client.finish().map(|_| ()));
        assert!(
            finish_result.is_err(),
            "a deterministically failing handler must fail the client"
        );

        let first = daemon.take_error().expect("handler failure not recorded");
        assert!(first.contains("boom"), "unexpected error: {first}");
        let report = daemon.shutdown();
        assert!(
            !report.errors.is_empty(),
            "reconnect's second failure missing from the report"
        );
    });
}

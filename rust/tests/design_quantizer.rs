//! Quantizer design stage, end to end: per-tile container-v3 property
//! tests (tile-designed decode equals the per-tile fake-quant reference
//! bit-exactly; corrupted/oversized spec records are container-level
//! errors mapped to their specific [`CodecError`] variants), kind-
//! preserving online re-design, and the rate/accuracy acceptance claim —
//! on a tensor with heterogeneous per-tile dynamic ranges, per-tile model
//! design beats every global static range that reaches the same
//! fake-quant MSE. All codec traffic goes through the `Codec` façade.

use lwfc::codec::{
    design_or, designer_for, ClipGranularity, DesignKind, EcqDesigner, EntropyKind,
    ModelOptimalDesigner, QuantDesigner, QuantKind, SubstreamDirectory,
};
use lwfc::modeling::Activation;
use lwfc::tensor::stats::TensorStats;
use lwfc::util::prop::{prop_check, Gen};
use lwfc::{Codec, CodecBuilder, CodecError, QuantSpec};

fn base_spec(levels: usize, c_max: f32) -> QuantSpec {
    QuantSpec::Uniform {
        c_min: 0.0,
        c_max,
        levels,
    }
}

fn designed_session(
    base: QuantSpec,
    designer: Box<dyn QuantDesigner>,
    threads: usize,
    tile: usize,
) -> Codec {
    CodecBuilder::new(base)
        .image_size(32)
        .threads(threads)
        .tile_elems(tile)
        .tile_designer(designer)
        .build()
}

/// A tensor whose tiles have very different dynamic ranges (scales cycle
/// per tile) — the workload per-tile design exists for.
fn heterogeneous_tensor(g: &mut Gen, tiles: usize, tile_elems: usize) -> Vec<f32> {
    let scales = [0.25f32, 1.0, 6.0];
    let mut xs = Vec::with_capacity(tiles * tile_elems);
    for t in 0..tiles {
        xs.extend(g.activation_vec(tile_elems, scales[t % scales.len()]));
    }
    xs
}

fn fake_quant_mse(xs: &[f32], decoded: &[f32]) -> f64 {
    assert_eq!(xs.len(), decoded.len());
    xs.iter()
        .zip(decoded)
        .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
        .sum::<f64>()
        / xs.len().max(1) as f64
}

// ---------------------------------------------------------------------------
// Container v3 property tests
// ---------------------------------------------------------------------------

#[test]
fn prop_tile_designed_decode_equals_per_tile_reference() {
    // For any tensor / tile size / thread count / designer, a v3 container
    // decodes to exactly what materializing each directory spec and
    // fake-quantizing its tile's inputs produces — bit-exact, element by
    // element. This is the per-tile generalization of the batched codec's
    // reconstruction-parity guarantee.
    prop_check("tile_designed_reference", 12, |g| {
        let tile_elems = g.usize_in(64, 1500);
        let tiles = g.usize_in(1, 6);
        let levels = g.usize_in(2, 8);
        let threads = g.usize_in(1, 6);
        let ecq = g.bool();
        let xs = heterogeneous_tensor(g, tiles, tile_elems);
        let model = ModelOptimalDesigner {
            levels,
            ..ModelOptimalDesigner::leaky(levels)
        };
        let boxed = |ecq: bool| -> Box<dyn QuantDesigner> {
            if ecq {
                Box::new(EcqDesigner::new(model))
            } else {
                Box::new(model)
            }
        };
        let mut codec = designed_session(base_spec(levels, 4.0), boxed(ecq), threads, tile_elems);
        let s = codec.encode(&xs);

        let (dir, _) = SubstreamDirectory::read(&s.bytes).map_err(|e| e.to_string())?;
        let specs = dir.specs.clone().ok_or("designed container must be v3")?;
        lwfc::prop_assert!(
            specs.len() == xs.len().div_ceil(tile_elems).max(1),
            "one spec per tile"
        );
        let decoded = codec.decode(&s.bytes).map_err(|e| e.to_string())?;
        lwfc::prop_assert!(decoded.values.len() == xs.len(), "length");
        lwfc::prop_assert!(
            decoded.info.designed_tiles == specs.len(),
            "DecodeInfo must report the designed-tile count"
        );
        for (t, spec) in specs.iter().enumerate() {
            let q = spec.materialize();
            let lo = t * tile_elems;
            let hi = (lo + tile_elems).min(xs.len());
            for i in lo..hi {
                lwfc::prop_assert!(
                    decoded.values[i] == q.fake_quant(xs[i]),
                    "tile {t} element {i}: {} vs {}",
                    decoded.values[i],
                    q.fake_quant(xs[i])
                );
            }
        }
        // The designed bytes are deterministic across thread counts.
        let again = designed_session(base_spec(levels, 4.0), boxed(ecq), 1, tile_elems).encode(&xs);
        lwfc::prop_assert!(again.bytes == s.bytes, "scheduling-dependent bytes");
        Ok(())
    });
}

#[test]
fn prop_corrupted_spec_records_are_container_errors() {
    // Any structural corruption of the v3 spec block — truncation, a bad
    // kind, an oversized level count, a broken range — must fail
    // SubstreamDirectory::read (and therefore both decode paths) before
    // any tile is decoded or fill-allocated, as the typed `SpecRecord`
    // variant naming the offending tile.
    prop_check("spec_block_corruption", 10, |g| {
        let tile_elems = g.usize_in(100, 800);
        let xs = heterogeneous_tensor(g, 3, tile_elems);
        let mut codec = designed_session(
            base_spec(4, 4.0),
            Box::new(ModelOptimalDesigner::leaky(4)),
            2,
            tile_elems,
        );
        let mut tol = CodecBuilder::new(base_spec(4, 4.0))
            .threads(2)
            .tile_elems(tile_elems)
            .tolerant(true)
            .build();
        let s = codec.encode(&xs);
        let (dir, payload_off) = SubstreamDirectory::read(&s.bytes).map_err(|e| e.to_string())?;
        let specs_start = dir.encoded_len()
            - dir
                .specs
                .as_ref()
                .unwrap()
                .iter()
                .map(|q| q.encoded_len())
                .sum::<usize>();

        // Truncating anywhere inside the spec block is fatal.
        let cut = g.usize_in(specs_start, payload_off - 1);
        lwfc::prop_assert!(
            matches!(
                SubstreamDirectory::read(&s.bytes[..cut]),
                Err(CodecError::SpecRecord { .. } | CodecError::Directory { .. })
            ),
            "cut at {cut} accepted or misclassified"
        );
        // An undefined spec kind is fatal — a `SpecRecord` error naming
        // tile 0, even for the tolerant decoder (a container whose design
        // block cannot be trusted decodes nothing).
        let mut bad = s.bytes.clone();
        bad[specs_start] = 0x41;
        let err = match codec.decode(&bad) {
            Err(e) => e,
            Ok(_) => return Err("bad spec kind accepted".into()),
        };
        lwfc::prop_assert!(
            matches!(err, CodecError::SpecRecord { tile: Some(0), .. }),
            "bad kind misclassified: {err:?}"
        );
        lwfc::prop_assert!(!err.is_tile_local(), "spec damage is never recoverable");
        lwfc::prop_assert!(
            matches!(tol.decode(&bad), Err(CodecError::SpecRecord { .. })),
            "tolerant accepted bad kind"
        );
        // An oversized ECQ level claim runs the record past the container.
        let mut bad = s.bytes.clone();
        bad[specs_start] = 1;
        bad[specs_start + 1] = 255;
        lwfc::prop_assert!(
            matches!(codec.decode(&bad), Err(CodecError::SpecRecord { .. })),
            "oversized spec accepted"
        );
        // A non-finite clip bound is fatal.
        let mut bad = s.bytes.clone();
        bad[specs_start + 6..specs_start + 10].copy_from_slice(&f32::INFINITY.to_le_bytes());
        lwfc::prop_assert!(
            matches!(codec.decode(&bad), Err(CodecError::SpecRecord { .. })),
            "non-finite range accepted"
        );
        Ok(())
    });
}

#[test]
fn ecq_tile_design_roundtrips_with_in_band_tables() {
    // Per-tile ECQ: every directory spec is entropy-constrained, the tile
    // stream headers carry the recon tables, and reconstruction is exact.
    let mut g = Gen::new("ecq_tiles", 0);
    let xs = heterogeneous_tensor(&mut g, 4, 3000);
    let mut codec = designed_session(
        base_spec(4, 4.0),
        Box::new(EcqDesigner::new(ModelOptimalDesigner::leaky(4))),
        3,
        3000,
    );
    let s = codec.encode(&xs);
    let (dir, _) = SubstreamDirectory::read(&s.bytes).unwrap();
    for spec in dir.specs.as_ref().unwrap() {
        assert_eq!(spec.kind(), QuantKind::EntropyConstrained);
        assert_eq!(spec.levels(), 4);
    }
    let decoded = codec.decode(&s.bytes).unwrap();
    assert_eq!(
        decoded.info.header.as_ref().unwrap().quant,
        QuantKind::EntropyConstrained
    );
    for (t, spec) in dir.specs.as_ref().unwrap().iter().enumerate() {
        let q = spec.materialize();
        for k in 0..3000 {
            let i = t * 3000 + k;
            assert_eq!(decoded.values[i], q.fake_quant(xs[i]), "tile {t} element {k}");
        }
    }
}

// ---------------------------------------------------------------------------
// Acceptance: rate/accuracy win on heterogeneous per-tile ranges
// ---------------------------------------------------------------------------

/// A tensor whose tiles share scale but sit at different operating points
/// (offsets) — heterogeneous per-tile *dynamic ranges* with no single
/// tile dominating the error budget. This is the workload where one
/// global clip range must stretch across the union of supports while
/// per-tile design anchors each range at its own tile.
fn offset_tensor(g: &mut Gen, tiles: usize, tile_elems: usize) -> Vec<f32> {
    let offsets = [0.0f32, 6.0, 12.0];
    let mut xs = Vec::with_capacity(tiles * tile_elems);
    for t in 0..tiles {
        let o = offsets[t % offsets.len()];
        xs.extend(g.activation_vec(tile_elems, 0.5).into_iter().map(|x| x + o));
    }
    xs
}

#[test]
fn tile_model_design_dominates_global_static_at_matched_mse() {
    // The acceptance claim: on a synthetic tensor with heterogeneous
    // per-tile dynamic ranges, `--clip-granularity tile --design model`
    // achieves strictly lower bits/element than the global static range
    // at equal-or-lower fake-quant MSE. Concretely: sweep global static
    // operating points (one model-designed range for the whole stream —
    // today's default encode — at N ∈ 2..=128, both zero-based and
    // signed ranges); the per-tile N=4 point must sit on the Pareto
    // frontier — every static point that reaches its MSE spends strictly
    // more bits, and no static point beats it on both axes.
    let mut g = Gen::new("rd_acceptance", 0);
    let tile_elems = 2048;
    let xs = offset_tensor(&mut g, 6, tile_elems);

    let mut codec = designed_session(
        base_spec(4, 16.0),
        Box::new(ModelOptimalDesigner::leaky(4)),
        4,
        tile_elems,
    );
    let tiled = codec.encode(&xs);
    let decoded = codec.decode(&tiled.bytes).unwrap();
    let bpe_tile = tiled.bits_per_element();
    let mse_tile = fake_quant_mse(&xs, &decoded.values);
    // The per-tile design must actually have designed something: specs
    // anchored at three different offsets.
    let (dir, _) = SubstreamDirectory::read(&tiled.bytes).unwrap();
    let specs = dir.specs.unwrap();
    assert!(
        specs[2].c_min() > specs[1].c_min() + 2.0
            && specs[1].c_min() > specs[0].c_min() + 2.0,
        "per-tile ranges should track the offsets: {specs:?}"
    );

    let stats = TensorStats::from_slice(&xs);
    let mut matched_any = false;
    for levels in [2usize, 4, 8, 16, 32, 64, 128] {
        for signed in [false, true] {
            // A global static range: the same model over whole-tensor
            // statistics, encoded as today's default single stream.
            let global = ModelOptimalDesigner {
                levels,
                signed_cmin: signed,
                ..ModelOptimalDesigner::leaky(levels)
            }
            .design(&stats, &xs)
            .expect("global design");
            let q = global.materialize();
            let mut static_codec = CodecBuilder::new(global).image_size(32).build();
            let s = static_codec.encode(&xs);
            let bpe_s = s.bits_per_element();
            let mse_s = xs
                .iter()
                .map(|&x| (x as f64 - q.fake_quant(x) as f64).powi(2))
                .sum::<f64>()
                / xs.len() as f64;
            if mse_s <= mse_tile {
                matched_any = true;
                assert!(
                    bpe_s > bpe_tile,
                    "global static N={levels} (signed={signed}) dominates tile design: \
                     {bpe_s:.4} bits/elem at mse {mse_s:.6} vs tile {bpe_tile:.4} at {mse_tile:.6}"
                );
            }
        }
    }
    assert!(
        matched_any,
        "no global static point reached the tile-design MSE {mse_tile:.6} — \
         comparison is vacuous, widen the static sweep"
    );
}

// ---------------------------------------------------------------------------
// Designer plumbing end to end (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn stream_design_matches_designer_output() {
    // `design_or` + a single-stream session is exactly what the CLI's
    // `--design model --clip-granularity stream` path runs.
    let mut g = Gen::new("stream_design", 0);
    let xs = g.activation_vec(20_000, 1.5);
    let base = QuantSpec::Uniform {
        c_min: 0.0,
        c_max: 9.0,
        levels: 4,
    };
    let designer = designer_for(
        DesignKind::Model,
        &base,
        Activation::LeakyRelu { slope: 0.1 },
        0.5,
    );
    let spec = design_or(designer.as_ref(), &xs, &base);
    assert_ne!(spec, base, "designer should improve on the hand-picked range");
    let mut codec = CodecBuilder::new(spec.clone())
        .image_size(32)
        .entropy(EntropyKind::Rans)
        .expect_elements(xs.len())
        .build();
    let s = codec.encode(&xs);
    let decoded = codec.decode(&s.bytes).unwrap();
    let header = decoded.info.header.as_ref().unwrap();
    assert_eq!(header.entropy, EntropyKind::Rans);
    assert_eq!(header.levels, spec.levels());
    let q = spec.materialize();
    for (i, (&x, &y)) in xs.iter().zip(&decoded.values).enumerate() {
        assert_eq!(y, q.fake_quant(x), "element {i}");
    }
}

#[test]
fn granularity_and_design_parse_roundtrip() {
    for (s, k) in [
        ("static", DesignKind::Static),
        ("model", DesignKind::Model),
        ("ecq", DesignKind::Ecq),
    ] {
        assert_eq!(DesignKind::parse(s).unwrap(), k);
        assert_eq!(k.name(), s);
    }
    for (s, gnl) in [
        ("stream", ClipGranularity::Stream),
        ("tile", ClipGranularity::Tile),
    ] {
        assert_eq!(ClipGranularity::parse(s).unwrap(), gnl);
        assert_eq!(gnl.name(), s);
    }
    // Unknown spellings map to the typed `Invalid` class.
    assert!(matches!(
        DesignKind::parse("nope"),
        Err(CodecError::Invalid { .. })
    ));
    assert!(matches!(
        ClipGranularity::parse("voxel"),
        Err(CodecError::Invalid { .. })
    ));
}

//! End-to-end serving benchmark over the real artifacts: requests/s and
//! per-stage time through edge fwd -> encode -> decode -> cloud fwd.
//! Skips (exit 0) if `make artifacts` has not run.

use lwfc::coordinator::{serve, CloudConfig, EdgeConfig, QuantSpec, ServeConfig, TaskKind};
use lwfc::runtime::Manifest;

fn main() {
    let Ok(m) = Manifest::load(&Manifest::default_dir()) else {
        println!("SKIP end_to_end bench: no artifacts (run `make artifacts`)");
        return;
    };
    let task = TaskKind::ClassifyResnet { split: 2 };
    for workers in [1usize, 2, 4] {
        let cfg = ServeConfig {
            edge: EdgeConfig {
                task,
                quant: QuantSpec::Uniform {
                    c_min: 0.0,
                    c_max: 1.45,
                    levels: 4,
                },
                val_seed: m.val_seed,
                batch: m.serve_batch,
                adaptive: None,
            },
            cloud: CloudConfig {
                task,
                val_seed: m.val_seed,
                batch: m.serve_batch,
                obj_threshold: 0.3,
            },
            edge_workers: workers,
            requests: 512,
            queue_capacity: 64,
            first_index: 0,
        };
        match serve(&m, cfg) {
            Ok(r) => println!(
                "edge_workers={workers}: {:.1} req/s, p50 {:.1} ms, p99 {:.1} ms, top1 {:.4}, {:.3} bits/elem",
                r.throughput_rps,
                r.latency_p50_s * 1e3,
                r.latency_p99_s * 1e3,
                r.metric,
                r.bits_per_element
            ),
            Err(e) => {
                eprintln!("serve failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

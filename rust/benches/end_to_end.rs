//! End-to-end pipeline benchmarks.
//!
//! Part 1 (always runs): the codec leg of the pipeline — batched encode →
//! wire bytes → batched decode on a paper-scale 256x56x56 feature tensor,
//! single-thread vs N-thread, reporting the scaling curve — plus a
//! serve-loop simulation of the cloud worker's steady state (decode a
//! stream of wire items) comparing a fresh allocation per item against
//! the `Codec::decode_into` reused buffer.
//!
//! Part 2 (needs `make artifacts`; skips cleanly otherwise): the full
//! serving stack (edge fwd → encode → queue → decode → cloud fwd),
//! requests/s across edge-worker and codec-thread counts.

use lwfc::codec::EntropyKind;
use lwfc::coordinator::{
    serve, CloudConfig, EdgeConfig, QuantSpec, ServeConfig, TaskKind, TransportKind,
};
use lwfc::runtime::Manifest;
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::prop::Gen;
use lwfc::{Codec, CodecBuilder};

fn batched_session(entropy: EntropyKind, threads: usize) -> Codec {
    CodecBuilder::new(QuantSpec::Uniform {
        c_min: 0.0,
        c_max: 1.5,
        levels: 4,
    })
    .image_size(32)
    .entropy(entropy)
    .threads(threads)
    .force_container()
    .build()
}

fn codec_pipeline_bench() {
    let mut b = Bench::new();
    let mut g = Gen::new("e2e_codec_pipeline", 0);
    let elements = 256 * 56 * 56; // the acceptance tensor: 256 x 56 x 56
    let xs = g.activation_vec(elements, 0.3);

    println!("-- batched encode+decode round-trip (256x56x56) --");
    for entropy in [EntropyKind::Cabac, EntropyKind::Rans] {
        for threads in [1usize, 2, 4, 8] {
            let mut codec = batched_session(entropy, threads);
            b.run(
                &format!("roundtrip_{entropy}/t{threads}"),
                Some(elements as u64),
                || {
                    let s = codec.encode(&xs);
                    let out = codec.decode(&s.bytes).unwrap();
                    black_box(out.values.len())
                },
            );
        }
        let s = batched_session(entropy, 4).encode(&xs);
        println!("   {entropy}: {:.4} bits/element on the wire", s.bits_per_element());
    }
    for entropy in ["cabac", "rans"] {
        let (t1, t4) = (
            b.find(&format!("roundtrip_{entropy}/t1")),
            b.find(&format!("roundtrip_{entropy}/t4")),
        );
        if let (Some(t1), Some(t4)) = (t1, t4) {
            println!(
                "{entropy} round-trip speedup t4/t1 = {:.2}x",
                t1.median_s / t4.median_s
            );
        }
    }
    if let (Some(c), Some(r)) = (b.find("roundtrip_cabac/t4"), b.find("roundtrip_rans/t4")) {
        println!("rANS round-trip speedup vs CABAC (t4) = {:.2}x", c.median_s / r.median_s);
    }

    // ---- serve-loop steady state: the cloud worker's decode leg ---------
    // A fleet of wire items (8 distinct tensors, cycled) decoded back to
    // back, the way `CloudWorker::process` drains a batch: `serve_alloc`
    // builds a fresh output vector per item (the pre-façade behavior),
    // `serve_reuse` drains the same items through one `decode_into`
    // scratch buffer.
    println!("-- serve-loop decode: fresh alloc vs decode_into reuse (t4) --");
    let item_elems = 64 * 56 * 56;
    let items: Vec<Vec<u8>> = (0..8u64)
        .map(|i| {
            let tensor = Gen::new("e2e_serve_items", i).activation_vec(item_elems, 0.3);
            batched_session(EntropyKind::Cabac, 4).encode(&tensor).bytes
        })
        .collect();
    let mut codec = batched_session(EntropyKind::Cabac, 4);
    b.run("serve_decode_alloc/t4", Some((8 * item_elems) as u64), || {
        let mut total = 0usize;
        for bytes in &items {
            total += codec.decode(bytes).unwrap().values.len();
        }
        black_box(total)
    });
    let mut codec = batched_session(EntropyKind::Cabac, 4);
    let mut scratch: Vec<f32> = Vec::new();
    b.run("serve_decode_reuse/t4", Some((8 * item_elems) as u64), || {
        let mut total = 0usize;
        for bytes in &items {
            codec.decode_into(bytes, &mut scratch).unwrap();
            total += scratch.len();
        }
        black_box(total)
    });
    if let (Some(a), Some(r)) = (b.find("serve_decode_alloc/t4"), b.find("serve_decode_reuse/t4")) {
        println!(
            "serve-loop decode_into reuse speedup = {:.2}x",
            a.median_s / r.median_s
        );
    }

    // ---- multi-client overlapping content: the decode cache's case ------
    // N simulated clients stream the same small shared corpus (static
    // backgrounds, padding tiles, unchanged frames all look like this at
    // fleet scale): every tile past the first client's first pass is a
    // byte-identical repeat. Cache-off decodes every payload through the
    // entropy stage; cache-on turns the repeats into memcpys. Same salt
    // for all clients — they are one tenant's fleet.
    println!("-- multi-client overlapping-content decode: cache off vs on (t4) --");
    const CLIENTS: usize = 4;
    let corpus: Vec<Vec<u8>> = (0..4u64)
        .map(|i| {
            let tensor = Gen::new("e2e_shared_corpus", i).activation_vec(item_elems, 0.3);
            batched_session(EntropyKind::Cabac, 4).encode(&tensor).bytes
        })
        .collect();
    let mut plain: Vec<Codec> = (0..CLIENTS)
        .map(|_| batched_session(EntropyKind::Cabac, 4))
        .collect();
    let total_elems = (CLIENTS * corpus.len() * item_elems) as u64;
    b.run("serve_decode_multiclient/off", Some(total_elems), || {
        let mut total = 0usize;
        for codec in &mut plain {
            for bytes in &corpus {
                codec.decode_into(bytes, &mut scratch).unwrap();
                total += scratch.len();
            }
        }
        black_box(total)
    });
    let cache = std::sync::Arc::new(lwfc::codec::DecodeCache::new(256 << 20));
    let mut cached: Vec<Codec> = (0..CLIENTS)
        .map(|_| {
            CodecBuilder::new(QuantSpec::Uniform {
                c_min: 0.0,
                c_max: 1.5,
                levels: 4,
            })
            .image_size(32)
            .entropy(EntropyKind::Cabac)
            .threads(4)
            .force_container()
            .decode_cache_shared(cache.clone())
            .build()
        })
        .collect();
    b.run("serve_decode_multiclient/cached", Some(total_elems), || {
        let mut total = 0usize;
        for codec in &mut cached {
            for bytes in &corpus {
                codec.decode_into(bytes, &mut scratch).unwrap();
                total += scratch.len();
            }
        }
        black_box(total)
    });
    let stats = cache.stats();
    println!(
        "cache: hits={} misses={} saved={}B evictions={} (nonzero hits prove the \
         entropy decoder was skipped)",
        stats.hits, stats.misses, stats.bytes_saved, stats.evictions
    );
    assert!(stats.hits > 0, "overlapping corpus must produce cache hits");
    if let (Some(off), Some(on)) = (
        b.find("serve_decode_multiclient/off"),
        b.find("serve_decode_multiclient/cached"),
    ) {
        println!(
            "multi-client overlapping-content cache speedup = {:.2}x",
            off.median_s / on.median_s
        );
    }
}

fn serving_bench(m: &Manifest) {
    let task = TaskKind::ClassifyResnet { split: 2 };
    for (workers, codec_threads) in [(1usize, 1usize), (2, 1), (2, 4), (4, 4)] {
        let cfg = ServeConfig {
            edge: EdgeConfig {
                task,
                quant: QuantSpec::Uniform {
                    c_min: 0.0,
                    c_max: 1.45,
                    levels: 4,
                },
                entropy: EntropyKind::Cabac,
                val_seed: m.val_seed,
                batch: m.serve_batch,
                design: lwfc::codec::DesignKind::Static,
                granularity: lwfc::codec::ClipGranularity::Stream,
                adaptive: None,
                threads: codec_threads,
                video: false,
                decode_cache_mb: 0,
            },
            cloud: CloudConfig {
                task,
                val_seed: m.val_seed,
                batch: m.serve_batch,
                obj_threshold: 0.3,
                threads: codec_threads,
                decode_cache: None,
                cache_salt: 0,
            },
            edge_workers: workers,
            requests: 512,
            queue_capacity: 64,
            first_index: 0,
            transport: TransportKind::Loopback,
        };
        match serve(m, cfg) {
            Ok(r) => println!(
                "edge_workers={workers} codec_threads={codec_threads}: {:.1} req/s, p50 {:.1} ms, p99 {:.1} ms, top1 {:.4}, {:.3} bits/elem",
                r.throughput_rps,
                r.latency_p50_s * 1e3,
                r.latency_p99_s * 1e3,
                r.metric,
                r.bits_per_element
            ),
            Err(e) => {
                eprintln!("serve failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn main() {
    codec_pipeline_bench();
    match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => serving_bench(&m),
        Err(_) => println!("SKIP serving bench: no artifacts (run `make artifacts`)"),
    }
}

//! Quantizer costs: Eq. (1) uniform indexing, ECQ (Algorithm 1) design
//! time on 100-image training sets, and non-uniform indexing.

use lwfc::codec::{design_ecq, EcqParams, UniformQuantizer};
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::prop::Gen;

fn main() {
    let mut b = Bench::new();
    let mut g = Gen::new("quantizer_bench", 0);
    let n = 8192usize;
    let xs = g.activation_vec(n, 0.3);

    let q = UniformQuantizer::new(0.0, 1.5, 4);
    b.run("uniform/index", Some(n as u64), || {
        let mut acc = 0u32;
        for &x in &xs {
            acc += q.index(x) as u32;
        }
        black_box(acc)
    });

    // ECQ design on the paper's protocol scale: 100 images x 8192 elems.
    let train = g.activation_vec(100 * 1024, 0.3); // trimmed for bench time
    for levels in [2usize, 4] {
        b.run(&format!("ecq/design/n{levels}"), Some(train.len() as u64), || {
            black_box(design_ecq(&train, 0.0, 1.5, EcqParams::pinned(levels, 0.02)).iterations)
        });
    }

    let d = design_ecq(&train, 0.0, 1.5, EcqParams::pinned(4, 0.02));
    b.run("ecq/index", Some(n as u64), || {
        let mut acc = 0u32;
        for &x in &xs {
            acc += d.quantizer.index(x) as u32;
        }
        black_box(acc)
    });
}

//! Lightweight-codec throughput: full encode (clip+quant+TU+entropy) and
//! decode, per level count, on activation-like tensors — plus the tiled
//! batched codec on a paper-scale 256x56x56 tensor, single-thread vs
//! N-thread, a CABAC vs 2-way-rANS vs 4-way-rANS backend comparison
//! (throughput and bits/element), the dispatched SIMD quantize kernels
//! against their scalar twins, and the serving hot path's `decode_into`
//! buffer reuse vs a fresh allocation per decode. This is the L3 hot
//! path, exercised through the `Codec` façade (the API the serving
//! layer uses).
//!
//! Writes a machine-readable baseline to `BENCH_codec.json` (override the
//! path with `LWFC_BENCH_JSON`; set it to `-` to skip the write) so later
//! PRs have a perf trajectory to compare against.

use lwfc::codec::{
    design_ecq, EcqParams, EntropyKind, ModelOptimalDesigner, QuantDesigner, UniformQuantizer,
    DEFAULT_TILE_ELEMS,
};
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::json::{num, s, Json};
use lwfc::util::prop::Gen;
use lwfc::{Codec, CodecBuilder, QuantSpec};

fn uniform(levels: usize, c_max: f32) -> QuantSpec {
    QuantSpec::Uniform {
        c_min: 0.0,
        c_max,
        levels,
    }
}

fn session(quant: impl Into<QuantSpec>, threads: usize, elements: usize) -> Codec {
    CodecBuilder::new(quant)
        .image_size(32)
        .threads(threads)
        .expect_elements(elements)
        .build()
}

fn main() {
    let mut b = Bench::new();
    let mut g = Gen::new("codec_bench", 0);
    let n = 8192usize; // one ci-resnet split tensor
    let xs = g.activation_vec(n, 0.3);

    println!("-- encode (8192-element split tensor) --");
    for levels in [2usize, 4, 8] {
        let mut codec = session(uniform(levels, 1.5), 1, n);
        b.run(&format!("encode/n{levels}"), Some(n as u64), || {
            black_box(codec.encode(&xs).bytes.len())
        });
    }

    println!("-- decode --");
    for levels in [2usize, 4, 8] {
        let mut codec = session(uniform(levels, 1.5), 1, n);
        let stream = codec.encode(&xs);
        b.run(&format!("decode/n{levels}"), Some(n as u64), || {
            black_box(codec.decode(&stream.bytes).unwrap().values.len())
        });
    }

    println!("-- fake-quant only (no entropy coding) --");
    let q = UniformQuantizer::new(0.0, 1.5, 4);
    b.run("fakequant/n4", Some(n as u64), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += q.fake_quant(x);
        }
        black_box(acc)
    });

    // ---- NonUniformQuantizer::index: linear scan (small N) vs binary
    // search (designed large-N quantizers switch past 16 thresholds) ----
    println!("-- non-uniform index (linear scan vs binary search) --");
    for levels in [4usize, 64] {
        let nq = design_ecq(&xs, 0.0, 1.5, EcqParams::pinned(levels, 0.001)).quantizer;
        b.run(&format!("nonuniform_index/n{levels}"), Some(n as u64), || {
            let mut acc = 0u32;
            for &x in &xs {
                acc = acc.wrapping_add(nq.index(x) as u32);
            }
            black_box(acc)
        });
    }

    // ---- batched codec: 256x56x56 tensor, thread scaling ----------------
    let big_n = 256 * 56 * 56; // 802,816 elements — the acceptance tensor
    let big = g.activation_vec(big_n, 0.3);

    println!("-- batched encode (256x56x56, N=4) --");
    for threads in [1usize, 2, 4, 8] {
        let mut codec = batched_session(threads, big_n);
        b.run(
            &format!("batched_encode/t{threads}"),
            Some(big_n as u64),
            || black_box(codec.encode(&big).bytes.len()),
        );
    }

    println!("-- batched decode (256x56x56, N=4) --");
    let encoded = batched_session(4, big_n).encode(&big);
    for threads in [1usize, 2, 4, 8] {
        let mut codec = batched_session(threads, big_n);
        b.run(
            &format!("batched_decode/t{threads}"),
            Some(big_n as u64),
            || black_box(codec.decode(&encoded.bytes).unwrap().values.len()),
        );
    }

    // ---- serving hot path: decode_into buffer reuse vs fresh alloc ------
    println!("-- decode_into reuse vs per-call allocation (t4 container, N=4) --");
    {
        let mut codec = batched_session(4, big_n);
        b.run("decode_alloc/n4", Some(big_n as u64), || {
            black_box(codec.decode(&encoded.bytes).unwrap().values.len())
        });
        let mut codec = batched_session(4, big_n);
        let mut buf: Vec<f32> = Vec::new();
        b.run("decode_into_reuse/n4", Some(big_n as u64), || {
            codec.decode_into(&encoded.bytes, &mut buf).unwrap();
            black_box(buf.len())
        });
    }

    // ---- content-addressed decode cache: cold miss vs warm hit ----------
    // The serve-loop case: the same container decoded repeatedly (padding
    // tiles, static backgrounds, unchanged frames at fleet scale). Cold
    // measures the miss path's overhead (key hash + insert on top of the
    // full entropy decode); warm measures the hit path (payload compare +
    // memcpy, no entropy decode).
    println!("-- decode cache: cold (miss+insert) vs warm (hit) (t4, N=4) --");
    {
        let cache = std::sync::Arc::new(lwfc::codec::DecodeCache::new(256 << 20));
        let mut codec = CodecBuilder::new(uniform(4, 1.5))
            .image_size(32)
            .threads(4)
            .force_container()
            .expect_elements(big_n)
            .decode_cache_shared(cache.clone())
            .build();
        let mut buf: Vec<f32> = Vec::new();
        b.run("cached_decode/cold", Some(big_n as u64), || {
            // Fresh cache per iteration: every tile misses and inserts.
            cache.clear();
            codec.decode_into(&encoded.bytes, &mut buf).unwrap();
            black_box(buf.len())
        });
        cache.clear();
        codec.decode_into(&encoded.bytes, &mut buf).unwrap(); // warm it
        b.run("cached_decode/warm", Some(big_n as u64), || {
            codec.decode_into(&encoded.bytes, &mut buf).unwrap();
            black_box(buf.len())
        });
        let stats = cache.stats();
        assert!(stats.hits > 0, "warm pass must hit");
        println!(
            "   cache: hits={} misses={} saved={}B",
            stats.hits, stats.misses, stats.bytes_saved
        );
    }

    // ---- SIMD quantize kernels vs their scalar twins (256x56x56, N=4) ---
    // The vector path is bit-exact against the scalar twin (the simd
    // module's differential tests pin that); this row quantifies the
    // speedup of the dispatched kernel on this machine.
    println!(
        "-- simd quantize/reconstruct vs scalar (256x56x56, N=4; kernels: {}) --",
        lwfc::codec::simd::active()
    );
    {
        use lwfc::codec::simd;
        let q = UniformQuantizer::new(0.0, 1.5, 4);
        let mut idx = vec![0u16; big_n];
        b.run("simd_quantize/vector", Some(big_n as u64), || {
            simd::quantize_slice(&q, &big, &mut idx);
            black_box(idx[big_n - 1])
        });
        b.run("simd_quantize/scalar", Some(big_n as u64), || {
            simd::scalar::quantize_slice(&q, &big, &mut idx);
            black_box(idx[big_n - 1])
        });
        let mut rec = vec![0f32; big_n];
        b.run("simd_reconstruct/vector", Some(big_n as u64), || {
            simd::reconstruct_slice(&q, &idx, &mut rec);
            black_box(rec[big_n - 1])
        });
        b.run("simd_reconstruct/scalar", Some(big_n as u64), || {
            simd::scalar::reconstruct_slice(&q, &idx, &mut rec);
            black_box(rec[big_n - 1])
        });
    }

    // ---- entropy backends head to head (256x56x56, N=4) -----------------
    println!("-- entropy backends (256x56x56, N=4, single stream) --");
    let mut bpe = std::collections::BTreeMap::new();
    for kind in [EntropyKind::Cabac, EntropyKind::Rans, EntropyKind::Rans4] {
        let mut codec = CodecBuilder::new(uniform(4, 1.5))
            .image_size(32)
            .entropy(kind)
            .expect_elements(big_n)
            .build();
        b.run(&format!("entropy_encode/{kind}"), Some(big_n as u64), || {
            black_box(codec.encode(&big).bytes.len())
        });
        let stream = codec.encode(&big);
        bpe.insert(kind.to_string(), stream.bits_per_element());
        println!("   {kind}: {:.4} bits/element", stream.bits_per_element());
        b.run(&format!("entropy_decode/{kind}"), Some(big_n as u64), || {
            black_box(codec.decode(&stream.bytes).unwrap().values.len())
        });
    }

    println!("-- batched rans (256x56x56, N=4) --");
    for threads in [1usize, 4] {
        // force_container: the t1 row must measure the container format
        // (like the CABAC rows), not the single-stream fallback.
        let mut codec = CodecBuilder::new(uniform(4, 1.5))
            .image_size(32)
            .entropy(EntropyKind::Rans)
            .threads(threads)
            .force_container()
            .build();
        b.run(
            &format!("batched_encode_rans/t{threads}"),
            Some(big_n as u64),
            || black_box(codec.encode(&big).bytes.len()),
        );
    }

    // ---- quantizer design stage: per-tile model design (container v3)
    // vs one global static range (today's default single stream), on a
    // tensor whose tiles sit at heterogeneous operating points — the
    // workload the design stage exists for -------------------------------
    println!("-- quantizer design (offset-heterogeneous 48-tile tensor, N=4) --");
    let tile_elems = DEFAULT_TILE_ELEMS;
    let offsets = [0.0f32, 6.0, 12.0];
    let mut hetero = Vec::with_capacity(48 * tile_elems);
    for t in 0..48 {
        let o = offsets[t % offsets.len()];
        hetero.extend(g.activation_vec(tile_elems, 0.5).into_iter().map(|x| x + o));
    }
    let mse_of = |decoded: &[f32]| -> f64 {
        hetero
            .iter()
            .zip(decoded)
            .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
            .sum::<f64>()
            / hetero.len() as f64
    };
    // Global static range: the model fit over the whole mixed tensor,
    // encoded as one stream (exactly `lwfc encode` without --design).
    let stats = lwfc::tensor::stats::TensorStats::from_slice(&hetero);
    let global = ModelOptimalDesigner {
        signed_cmin: false, // today's zero-based default range
        ..ModelOptimalDesigner::leaky(4)
    }
    .design(&stats, &hetero)
    .expect("global design");
    let gq = global.materialize();
    let mut static_codec = session(global.clone(), 1, hetero.len());
    let static_stream = static_codec.encode(&hetero);
    let bpe_static = static_stream.bits_per_element();
    let mse_static = mse_of(&hetero.iter().map(|&x| gq.fake_quant(x)).collect::<Vec<_>>());

    let mut tile_codec = CodecBuilder::new(global.clone())
        .image_size(32)
        .threads(4)
        .tile_elems(tile_elems)
        .tile_designer(Box::new(ModelOptimalDesigner::leaky(4)))
        .build();
    b.run("design_encode/tile_model", Some(hetero.len() as u64), || {
        black_box(tile_codec.encode(&hetero).bytes.len())
    });
    let tiled = tile_codec.encode(&hetero);
    let bpe_tile = tiled.bits_per_element();
    let mse_tile = mse_of(&tile_codec.decode(&tiled.bytes).unwrap().values);
    println!(
        "   static global range (single stream): {bpe_static:.4} bits/element, mse {mse_static:.6}\n   \
         per-tile model design (container v3): {bpe_tile:.4} bits/element, mse {mse_tile:.6}"
    );
    // The RD claim container v3 is for: to match the per-tile design's
    // MSE, a global static range needs many more levels — and then spends
    // strictly more bits (the tile point sits on the Pareto frontier; the
    // acceptance test pins this, the bench quantifies it).
    let mut matched: Option<(usize, f64, f64)> = None;
    for levels in [4usize, 8, 16, 32, 64, 128] {
        let d = ModelOptimalDesigner {
            levels,
            signed_cmin: false,
            ..ModelOptimalDesigner::leaky(levels)
        }
        .design(&stats, &hetero)
        .expect("global design");
        let dq = d.materialize();
        let stream_n = session(d, 1, hetero.len()).encode(&hetero);
        let msen = mse_of(&hetero.iter().map(|&x| dq.fake_quant(x)).collect::<Vec<_>>());
        if msen <= mse_tile {
            matched = Some((levels, stream_n.bits_per_element(), msen));
            break;
        }
    }
    match matched {
        Some((levels, bpe, mse)) => println!(
            "   static needs N={levels} to reach that MSE: {bpe:.4} bits/element \
             (mse {mse:.6}) -> per-tile design saves {:.1}%",
            100.0 * (1.0 - bpe_tile / bpe)
        ),
        None => println!("   static never reached the per-tile MSE up to N=128"),
    }
    let bpe_static_matched = matched.map(|(_, bpe, _)| bpe);

    // ---- temporal coding: stream session (container v4) vs per-frame
    // intra on a correlated 4-frame sequence — the video workload the
    // session exists for ------------------------------------------------
    println!("-- temporal coding (4 correlated 256x56x56 frames, N=4) --");
    let mut frames = vec![big.clone()];
    for _ in 1..4 {
        let noise = g.activation_vec(big_n, 0.3);
        let prev = frames.last().unwrap();
        frames.push(
            prev.iter()
                .zip(&noise)
                .map(|(&x, &e)| x + 0.02 * (e - 0.1))
                .collect(),
        );
    }
    let video_session = || {
        CodecBuilder::new(uniform(4, 1.5))
            .image_size(32)
            .threads(4)
            .stream_session()
            .build()
    };
    let total_n = (big_n * frames.len()) as u64;
    {
        let mut codec = batched_session(4, big_n);
        b.run("temporal_encode/intra", Some(total_n), || {
            let mut bytes = 0usize;
            for f in &frames {
                bytes += codec.encode(f).bytes.len();
            }
            black_box(bytes)
        });
    }
    {
        let mut codec = video_session();
        b.run("temporal_encode/inter", Some(total_n), || {
            // Reset per iteration so every measurement codes the same
            // intra-then-inter sequence.
            codec.reset_stream();
            let mut bytes = 0usize;
            for f in &frames {
                bytes += codec.encode(f).bytes.len();
            }
            black_box(bytes)
        });
    }
    let mut intra_codec = batched_session(4, big_n);
    let mut inter_codec = video_session();
    let (mut intra_bytes, mut inter_bytes) = (0usize, 0usize);
    for f in &frames {
        intra_bytes += intra_codec.encode(f).bytes.len();
        inter_bytes += inter_codec.encode(f).bytes.len();
    }
    let bpe_intra_video = intra_bytes as f64 * 8.0 / total_n as f64;
    let bpe_inter_video = inter_bytes as f64 * 8.0 / total_n as f64;
    let tstats = inter_codec.temporal_stats().expect("session stats");
    println!(
        "   per-frame intra: {bpe_intra_video:.4} bits/element\n   \
         stream session:  {bpe_inter_video:.4} bits/element \
         ({} intra / {} inter tiles, residuals {:.4} bits/element) \
         -> saves {:.1}%",
        tstats.intra_tiles,
        tstats.inter_tiles,
        tstats.residual_bits_per_element(),
        100.0 * (1.0 - bpe_inter_video / bpe_intra_video)
    );

    let speedup = |a: &str, z: &str| -> Option<f64> {
        Some(b.find(a)?.median_s / b.find(z)?.median_s)
    };
    if let Some(sx) = speedup("entropy_encode/cabac", "entropy_encode/rans") {
        println!("\nrANS encode speedup vs CABAC: {sx:.2}x");
    }
    if let Some(sx) = speedup("entropy_decode/cabac", "entropy_decode/rans") {
        println!("rANS decode speedup vs CABAC: {sx:.2}x");
    }
    if let Some(sx) = speedup("entropy_decode/rans", "entropy_decode/rans4") {
        println!("4-way rANS decode speedup vs 2-way: {sx:.2}x");
    }
    if let Some(sx) = speedup("simd_quantize/scalar", "simd_quantize/vector") {
        println!(
            "SIMD quantize speedup vs scalar ({}): {sx:.2}x",
            lwfc::codec::simd::active()
        );
    }
    if let Some(sx) = speedup("batched_encode/t1", "batched_encode/t4") {
        println!("\nbatched encode speedup t4 vs t1: {sx:.2}x (target: >= 2x)");
    }
    if let Some(sx) = speedup("batched_decode/t1", "batched_decode/t4") {
        println!("batched decode speedup t4 vs t1: {sx:.2}x");
    }
    if let Some(sx) = speedup("decode_alloc/n4", "decode_into_reuse/n4") {
        println!("decode_into buffer-reuse speedup vs fresh alloc: {sx:.2}x");
    }
    if let Some(sx) = speedup("cached_decode/cold", "cached_decode/warm") {
        println!("decode-cache warm-hit speedup vs cold miss: {sx:.2}x");
    }

    // ---- machine-readable baseline --------------------------------------
    // Default to the committed baseline at the repo root (one level above
    // the cargo package), independent of the bench's working directory.
    let json_path = std::env::var("LWFC_BENCH_JSON").unwrap_or_else(|_| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|repo_root| repo_root.join("BENCH_codec.json"))
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_codec.json"))
            .to_string_lossy()
            .into_owned()
    });
    if json_path != "-" {
        let meta = vec![
            ("bench", s("codec")),
            ("tensor", s("256x56x56 f32 activations, N=4, tile 16384")),
            (
                "encode_speedup_t4_vs_t1",
                speedup("batched_encode/t1", "batched_encode/t4").map_or(Json::Null, num),
            ),
            (
                "decode_speedup_t4_vs_t1",
                speedup("batched_decode/t1", "batched_decode/t4").map_or(Json::Null, num),
            ),
            (
                "rans_encode_speedup_vs_cabac",
                speedup("entropy_encode/cabac", "entropy_encode/rans").map_or(Json::Null, num),
            ),
            (
                "rans_decode_speedup_vs_cabac",
                speedup("entropy_decode/cabac", "entropy_decode/rans").map_or(Json::Null, num),
            ),
            // 4-way interleave over the 2-way baseline (same tables; the
            // win is wider independent decode states).
            (
                "rans4_decode_speedup_vs_rans2",
                speedup("entropy_decode/rans", "entropy_decode/rans4").map_or(Json::Null, num),
            ),
            // Dispatched vector quantize kernel over its scalar twin
            // (which kernel set ran is recorded in `simd_kernels`).
            (
                "simd_quantize_speedup",
                speedup("simd_quantize/scalar", "simd_quantize/vector").map_or(Json::Null, num),
            ),
            ("simd_kernels", s(lwfc::codec::simd::active())),
            // Serving hot path: fresh-allocation decode over reused-buffer
            // decode_into (> 1.0 means the reuse wins).
            (
                "decode_into_reuse_speedup",
                speedup("decode_alloc/n4", "decode_into_reuse/n4").map_or(Json::Null, num),
            ),
            // Content-addressed decode cache: warm-hit decode (payload
            // compare + memcpy) over cold miss+insert decode.
            (
                "decode_cache_warm_speedup",
                speedup("cached_decode/cold", "cached_decode/warm").map_or(Json::Null, num),
            ),
            (
                "bits_per_element_cabac",
                bpe.get("cabac").copied().map_or(Json::Null, num),
            ),
            (
                "bits_per_element_rans",
                bpe.get("rans").copied().map_or(Json::Null, num),
            ),
            (
                "bits_per_element_rans4",
                bpe.get("rans4").copied().map_or(Json::Null, num),
            ),
            // Quantizer-design rows (heterogeneous-tile tensor, N=4).
            ("bits_per_element_static_hetero", num(bpe_static)),
            ("bits_per_element_tile_model_hetero", num(bpe_tile)),
            ("mse_static_hetero", num(mse_static)),
            ("mse_tile_model_hetero", num(mse_tile)),
            (
                "bits_per_element_static_mse_matched",
                bpe_static_matched.map_or(Json::Null, num),
            ),
            // Temporal rows (correlated 4-frame video sequence, N=4):
            // identical reconstructions by construction, so the delta is
            // pure rate.
            ("intra_bits_per_element_video", num(bpe_intra_video)),
            ("inter_bits_per_element_video", num(bpe_inter_video)),
            (
                "inter_residual_bits_per_element",
                num(tstats.residual_bits_per_element()),
            ),
        ];
        match b.write_json(std::path::Path::new(&json_path), meta) {
            Ok(()) => println!("wrote {json_path}"),
            Err(e) => eprintln!("could not write {json_path}: {e}"),
        }
    }
}

/// A batched session: always the tiled container (the pool has
/// `threads` workers; the container format does not depend on the pool,
/// so `t1` measures single-worker container throughput, not the
/// single-stream format).
fn batched_session(threads: usize, elements: usize) -> Codec {
    // `threads(1)` would select the single-stream format; a tile designer
    // also forces the container, but changes the bytes. The honest t1
    // container measurement drives the same engine with a 1-worker pool —
    // which `.threads(1)` cannot express — so we pin the container format
    // with `.force_container()`.
    CodecBuilder::new(QuantSpec::Uniform {
        c_min: 0.0,
        c_max: 1.5,
        levels: 4,
    })
    .image_size(32)
    .threads(threads)
    .force_container()
    .expect_elements(elements)
    .build()
}

//! Lightweight-codec throughput: full encode (clip+quant+TU+entropy) and
//! decode, per level count, on activation-like tensors — plus the tiled
//! batched codec on a paper-scale 256x56x56 tensor, single-thread vs
//! N-thread, and a CABAC-vs-rANS backend comparison (throughput and
//! bits/element) on the same tensor. This is the L3 hot path.
//!
//! Writes a machine-readable baseline to `BENCH_codec.json` (override the
//! path with `LWFC_BENCH_JSON`; set it to `-` to skip the write) so later
//! PRs have a perf trajectory to compare against.

use lwfc::codec::{
    batch, decode, Encoder, EncoderConfig, EntropyKind, Quantizer, UniformQuantizer,
};
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::json::{num, s, Json};
use lwfc::util::prop::Gen;
use lwfc::util::threadpool::ThreadPool;

fn main() {
    let mut b = Bench::new();
    let mut g = Gen::new("codec_bench", 0);
    let n = 8192usize; // one ci-resnet split tensor
    let xs = g.activation_vec(n, 0.3);

    println!("-- encode (8192-element split tensor) --");
    for levels in [2usize, 4, 8] {
        let q = Quantizer::Uniform(UniformQuantizer::new(0.0, 1.5, levels));
        let mut enc = Encoder::new(EncoderConfig::classification(q, 32));
        b.run(&format!("encode/n{levels}"), Some(n as u64), || {
            black_box(enc.encode(&xs).bytes.len())
        });
    }

    println!("-- decode --");
    for levels in [2usize, 4, 8] {
        let q = Quantizer::Uniform(UniformQuantizer::new(0.0, 1.5, levels));
        let mut enc = Encoder::new(EncoderConfig::classification(q, 32));
        let stream = enc.encode(&xs);
        b.run(&format!("decode/n{levels}"), Some(n as u64), || {
            black_box(decode(&stream.bytes, n).unwrap().0.len())
        });
    }

    println!("-- fake-quant only (no entropy coding) --");
    let q = UniformQuantizer::new(0.0, 1.5, 4);
    b.run("fakequant/n4", Some(n as u64), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += q.fake_quant(x);
        }
        black_box(acc)
    });

    // ---- batched codec: 256x56x56 tensor, thread scaling ----------------
    let big_n = 256 * 56 * 56; // 802,816 elements — the acceptance tensor
    let big = g.activation_vec(big_n, 0.3);
    let cfg = EncoderConfig::classification(
        Quantizer::Uniform(UniformQuantizer::new(0.0, 1.5, 4)),
        32,
    );

    println!("-- batched encode (256x56x56, N=4) --");
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        b.run(
            &format!("batched_encode/t{threads}"),
            Some(big_n as u64),
            || {
                black_box(
                    batch::encode_batched(&cfg, &big, batch::DEFAULT_TILE_ELEMS, &pool)
                        .bytes
                        .len(),
                )
            },
        );
    }

    println!("-- batched decode (256x56x56, N=4) --");
    let encoded = batch::encode_batched(&cfg, &big, batch::DEFAULT_TILE_ELEMS, &ThreadPool::new(4));
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        b.run(
            &format!("batched_decode/t{threads}"),
            Some(big_n as u64),
            || black_box(batch::decode_batched(&encoded.bytes, &pool).unwrap().0.len()),
        );
    }

    // ---- entropy backends head to head (256x56x56, N=4) -----------------
    println!("-- entropy backends (256x56x56, N=4, single stream) --");
    let mut bpe = std::collections::BTreeMap::new();
    for kind in [EntropyKind::Cabac, EntropyKind::Rans] {
        let kcfg = cfg.clone().with_entropy(kind);
        let mut enc = Encoder::new(kcfg);
        b.run(&format!("entropy_encode/{kind}"), Some(big_n as u64), || {
            black_box(enc.encode(&big).bytes.len())
        });
        let stream = enc.encode(&big);
        bpe.insert(kind.to_string(), stream.bits_per_element());
        println!("   {kind}: {:.4} bits/element", stream.bits_per_element());
        b.run(&format!("entropy_decode/{kind}"), Some(big_n as u64), || {
            black_box(decode(&stream.bytes, big_n).unwrap().0.len())
        });
    }

    println!("-- batched rans (256x56x56, N=4) --");
    let rans_cfg = cfg.clone().with_entropy(EntropyKind::Rans);
    for threads in [1usize, 4] {
        let pool = ThreadPool::new(threads);
        b.run(
            &format!("batched_encode_rans/t{threads}"),
            Some(big_n as u64),
            || {
                black_box(
                    batch::encode_batched(&rans_cfg, &big, batch::DEFAULT_TILE_ELEMS, &pool)
                        .bytes
                        .len(),
                )
            },
        );
    }

    let speedup = |a: &str, z: &str| -> Option<f64> {
        Some(b.find(a)?.median_s / b.find(z)?.median_s)
    };
    if let Some(sx) = speedup("entropy_encode/cabac", "entropy_encode/rans") {
        println!("\nrANS encode speedup vs CABAC: {sx:.2}x");
    }
    if let Some(sx) = speedup("entropy_decode/cabac", "entropy_decode/rans") {
        println!("rANS decode speedup vs CABAC: {sx:.2}x");
    }
    if let Some(sx) = speedup("batched_encode/t1", "batched_encode/t4") {
        println!("\nbatched encode speedup t4 vs t1: {sx:.2}x (target: >= 2x)");
    }
    if let Some(sx) = speedup("batched_decode/t1", "batched_decode/t4") {
        println!("batched decode speedup t4 vs t1: {sx:.2}x");
    }

    // ---- machine-readable baseline --------------------------------------
    // Default to the committed baseline at the repo root (one level above
    // the cargo package), independent of the bench's working directory.
    let json_path = std::env::var("LWFC_BENCH_JSON").unwrap_or_else(|_| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|repo_root| repo_root.join("BENCH_codec.json"))
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_codec.json"))
            .to_string_lossy()
            .into_owned()
    });
    if json_path != "-" {
        let meta = vec![
            ("bench", s("codec")),
            ("tensor", s("256x56x56 f32 activations, N=4, tile 16384")),
            (
                "encode_speedup_t4_vs_t1",
                speedup("batched_encode/t1", "batched_encode/t4").map_or(Json::Null, num),
            ),
            (
                "decode_speedup_t4_vs_t1",
                speedup("batched_decode/t1", "batched_decode/t4").map_or(Json::Null, num),
            ),
            (
                "rans_encode_speedup_vs_cabac",
                speedup("entropy_encode/cabac", "entropy_encode/rans").map_or(Json::Null, num),
            ),
            (
                "rans_decode_speedup_vs_cabac",
                speedup("entropy_decode/cabac", "entropy_decode/rans").map_or(Json::Null, num),
            ),
            (
                "bits_per_element_cabac",
                bpe.get("cabac").copied().map_or(Json::Null, num),
            ),
            (
                "bits_per_element_rans",
                bpe.get("rans").copied().map_or(Json::Null, num),
            ),
        ];
        match b.write_json(std::path::Path::new(&json_path), meta) {
            Ok(()) => println!("wrote {json_path}"),
            Err(e) => eprintln!("could not write {json_path}: {e}"),
        }
    }
}

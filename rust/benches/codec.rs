//! Lightweight-codec throughput: full encode (clip+quant+TU+CABAC) and
//! decode, per level count, on activation-like tensors. This is the L3
//! hot path — the §Perf targets in EXPERIMENTS.md come from here.

use lwfc::codec::{decode, Encoder, EncoderConfig, Quantizer, UniformQuantizer};
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::prop::Gen;

fn main() {
    let mut b = Bench::new();
    let mut g = Gen::new("codec_bench", 0);
    let n = 8192usize; // one ci-resnet split tensor
    let xs = g.activation_vec(n, 0.3);

    println!("-- encode (8192-element split tensor) --");
    for levels in [2usize, 4, 8] {
        let q = Quantizer::Uniform(UniformQuantizer::new(0.0, 1.5, levels));
        let mut enc = Encoder::new(EncoderConfig::classification(q, 32));
        b.run(&format!("encode/n{levels}"), Some(n as u64), || {
            black_box(enc.encode(&xs).bytes.len())
        });
    }

    println!("-- decode --");
    for levels in [2usize, 4, 8] {
        let q = Quantizer::Uniform(UniformQuantizer::new(0.0, 1.5, levels));
        let mut enc = Encoder::new(EncoderConfig::classification(q, 32));
        let stream = enc.encode(&xs);
        b.run(&format!("decode/n{levels}"), Some(n as u64), || {
            black_box(decode(&stream.bytes, n).unwrap().0.len())
        });
    }

    println!("-- fake-quant only (no entropy coding) --");
    let q = UniformQuantizer::new(0.0, 1.5, 4);
    b.run("fakequant/n4", Some(n as u64), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += q.fake_quant(x);
        }
        black_box(acc)
    });
}

//! CABAC engine throughput (bins/s), encode and decode, skewed and
//! uniform bins — the per-bin cost bounds the whole codec.

use lwfc::codec::cabac::{CabacDecoder, CabacEncoder, Context};
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::rng::SplitMix64;

fn main() {
    let mut b = Bench::new();
    let n = 100_000usize;
    let mut rng = SplitMix64::new(1);
    let skewed: Vec<bool> = (0..n).map(|_| rng.next_u64() % 8 == 0).collect();
    let uniform: Vec<bool> = (0..n).map(|_| rng.next_u64() & 1 == 1).collect();

    for (label, bits) in [("skewed_p0.125", &skewed), ("uniform_p0.5", &uniform)] {
        b.run(&format!("encode/{label}"), Some(n as u64), || {
            let mut ctx = Context::default();
            let mut enc = CabacEncoder::new();
            for &bit in bits.iter() {
                enc.encode(&mut ctx, bit);
            }
            black_box(enc.finish().len())
        });
        let mut ctx = Context::default();
        let mut enc = CabacEncoder::new();
        for &bit in bits.iter() {
            enc.encode(&mut ctx, bit);
        }
        let bytes = enc.finish();
        b.run(&format!("decode/{label}"), Some(n as u64), || {
            let mut ctx = Context::default();
            let mut dec = CabacDecoder::new(&bytes);
            let mut acc = 0u32;
            for _ in 0..n {
                acc += dec.decode(&mut ctx) as u32;
            }
            black_box(acc)
        });
    }

    b.run("encode/bypass", Some(n as u64), || {
        let mut enc = CabacEncoder::new();
        for &bit in uniform.iter() {
            enc.encode_bypass(bit);
        }
        black_box(enc.finish().len())
    });
}

//! §III-E complexity comparison on identical content: lightweight codec
//! vs the HEVC-SCC-like picture codec (encode side). The paper's claim is
//! that the lightweight codec is >90% less complex; here both codecs are
//! measured on the same feature-map-like tensors.

use lwfc::baseline::{HevcLikeConfig, HevcLikeEncoder};
use lwfc::codec::UniformQuantizer;
use lwfc::tensor::mosaic::{mosaic, PixelRange};
use lwfc::tensor::Tensor;
use lwfc::util::bench::{black_box, Bench};
use lwfc::util::prop::Gen;
use lwfc::CodecBuilder;

fn main() {
    let mut b = Bench::new();
    let mut g = Gen::new("b_vs_l", 0);
    let (h, w, c) = (16usize, 16usize, 32usize);
    let n = h * w * c;
    let xs = g.activation_vec(n, 0.3);
    let t = Tensor::new(&[h, w, c], xs.clone());
    let range = PixelRange::of(&t);

    let mut codec = CodecBuilder::new(UniformQuantizer::new(0.0, 1.5, 4))
        .image_size(32)
        .build();
    b.run("lightweight/encode", Some(n as u64), || {
        black_box(codec.encode(&xs).bytes.len())
    });

    for (label, ts) in [("ts", true), ("dct_only", false)] {
        let cfg = HevcLikeConfig {
            qp: 24,
            transform_skip: ts,
        };
        let hevc = HevcLikeEncoder::new(cfg);
        b.run(&format!("hevc_like/encode/{label}"), Some(n as u64), || {
            let (pic, _) = mosaic(&t, range);
            black_box(hevc.encode(&pic).bytes.len())
        });
    }

    // Ratio summary (paper: lightweight <10% of HEVC complexity).
    let light = b.find("lightweight/encode").unwrap().median_s;
    let heavy = b.find("hevc_like/encode/ts").unwrap().median_s;
    println!("\nlightweight/baseline wall-clock ratio: {:.2}% (paper claim: <10%)", 100.0 * light / heavy);
}

// Fixture: a bare truncating cast on a serialization path. The count
// silently wraps past u16::MAX; the lint must demand `try_from`.

pub fn write_count(count: usize, out: &mut Vec<u8>) {
    out.extend_from_slice(&(count as u16).to_le_bytes());
}

// Fixture: an unwrap on the decode path, the exact bug class the
// panic-freedom lint exists to catch.

pub fn read_count(bytes: &[u8]) -> u32 {
    let arr: [u8; 4] = bytes[..4].try_into().unwrap();
    u32::from_le_bytes(arr)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v: Option<u8> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}

// Fixture: an allowlisted module with an undocumented unsafe block.
// `cargo xtask analyze` must flag the block below (no SAFETY comment).

pub fn quantize(xs: &[f32], out: &mut [u8]) {
    let p = xs.as_ptr();
    unsafe {
        let _ = *p;
    }
    out[0] = 0;
}

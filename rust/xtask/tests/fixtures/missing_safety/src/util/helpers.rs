// Fixture: unsafe outside the audited allowlist. The SAFETY comment is
// present, but the module is not allowlisted, so the audit must still
// flag it.

pub fn transmute_len(v: &[u8]) -> usize {
    // SAFETY: documented, but this module is not on the unsafe allowlist.
    unsafe { v.as_ptr().add(v.len()).offset_from(v.as_ptr()) as usize }
}

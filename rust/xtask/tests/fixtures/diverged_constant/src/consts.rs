// Fixture: Rust constants that diverged from the Python mirror —
// NET_VERSION was bumped to 4 here without touching the generator.

pub const BATCH_MAGIC: [u8; 4] = *b"LWFB";
pub const BATCH_MIN_VERSION: u8 = 1;
pub const BATCH_VERSION_PLAIN: u8 = 2;
pub const BATCH_VERSION: u8 = 3;
pub const BATCH_VERSION_TEMPORAL: u8 = 4;

pub const ENTROPY_ID_CABAC: u8 = 0;
pub const ENTROPY_ID_RANS: u8 = 1;
pub const ENTROPY_ID_RANS4: u8 = 3;

pub const NET_MAGIC: [u8; 4] = *b"LWFN";
pub const NET_VERSION: u8 = 4;
pub const NET_MIN_VERSION: u8 = 1;

pub const FRAME_KIND_ITEM: u8 = 0;
pub const FRAME_KIND_OUTCOME: u8 = 1;
pub const FRAME_KIND_BUSY: u8 = 2;
pub const FRAME_KIND_RESET: u8 = 3;

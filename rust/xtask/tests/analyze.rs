//! Integration tests for `cargo xtask analyze`.
//!
//! The contract, end to end: the real tree is clean, and each fixture
//! tree with one injected violation trips exactly the lint built to
//! catch it.

use std::path::PathBuf;
use xtask::{casts, consts_diff, panics, unsafe_audit};

fn real_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits under rust/")
        .to_path_buf()
}

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

#[test]
fn real_tree_is_clean() {
    let findings = xtask::analyze(&real_root());
    assert!(
        findings.is_empty(),
        "the committed tree must pass its own analyze gate:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

#[test]
fn missing_safety_comment_is_caught() {
    let findings = unsafe_audit::check(&fixture("missing_safety"));
    assert!(
        findings.iter().any(|f| f.file == "src/codec/simd.rs" && f.message.contains("SAFETY")),
        "expected an undocumented-unsafe finding, got: {findings:?}"
    );
    assert!(
        findings.iter().any(|f| f.file == "src/util/helpers.rs" && f.message.contains("allowlist")),
        "expected an outside-allowlist finding, got: {findings:?}"
    );
}

#[test]
fn decode_path_unwrap_is_caught() {
    let findings = panics::check(&fixture("decode_unwrap"));
    assert!(
        findings.iter().any(|f| f.file == "src/codec/header.rs" && f.message.contains(".unwrap()")),
        "expected a panic-freedom finding, got: {findings:?}"
    );
    assert!(
        !findings.iter().any(|f| f.line >= 10),
        "the #[cfg(test)] region must be exempt, got: {findings:?}"
    );
}

#[test]
fn diverged_constant_is_caught() {
    let findings = consts_diff::check(&fixture("diverged_constant"));
    assert!(
        findings
            .iter()
            .any(|f| f.message.contains("NET_VERSION") && f.message.contains("diverged")),
        "expected a consts-diff finding for NET_VERSION, got: {findings:?}"
    );
    assert!(
        !findings.iter().any(|f| f.message.contains("BATCH_")),
        "constants that agree must not be flagged, got: {findings:?}"
    );
}

#[test]
fn bare_truncating_cast_is_caught() {
    let findings = casts::check(&fixture("bare_cast"));
    assert!(
        findings.iter().any(|f| f.file == "src/codec/header.rs" && f.message.contains("as u16")),
        "expected a truncating-cast finding, got: {findings:?}"
    );
}

#[test]
fn full_analyze_rejects_every_fixture() {
    for name in ["missing_safety", "decode_unwrap", "diverged_constant", "bare_cast"] {
        let findings = xtask::analyze(&fixture(name));
        assert!(!findings.is_empty(), "fixture `{name}` must fail the full analyze pass");
    }
}

//! Lint 5: exhaustive dispatch.
//!
//! When a new entropy backend, container version, or wire frame kind is
//! added, it must be handled at *every* dispatch site — encode, decode,
//! sniff, and the CLI — not just the one the author was looking at.
//! `match` exhaustiveness does not help here: most of these sites match
//! on raw `u8`s (with a rejecting wildcard arm) or on strings, so a
//! forgotten variant compiles clean and fails at runtime. This lint
//! pins each site to the tokens it must keep handling.

use crate::scan::{has_token, Finding, SourceFile};
use std::path::Path;

pub const LINT: &str = "exhaustive-dispatch";

/// Where to look for a required token.
enum In {
    /// Masked non-test code (identifier-ish tokens).
    Code,
    /// Raw non-comment, non-test lines (string-literal match arms and
    /// CLI help text, which masking blanks out).
    Raw,
}

struct Site {
    file: &'static str,
    role: &'static str,
    token: &'static str,
    place: In,
}

const SITES: &[Site] = &[
    // Entropy-backend dispatch: encode enum, decode-by-id, name parsing.
    Site {
        file: "src/codec/entropy.rs",
        role: "backend encode dispatch",
        token: "EntropyKind::Cabac",
        place: In::Code,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend encode dispatch",
        token: "EntropyKind::Rans",
        place: In::Code,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend encode dispatch",
        token: "EntropyKind::Rans4",
        place: In::Code,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend id decode arm",
        token: "ENTROPY_ID_CABAC =>",
        place: In::Code,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend id decode arm",
        token: "ENTROPY_ID_RANS =>",
        place: In::Code,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend id decode arm",
        token: "ENTROPY_ID_RANS4 =>",
        place: In::Code,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend name parse arm",
        token: "\"cabac\" =>",
        place: In::Raw,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend name parse arm",
        token: "\"rans\" =>",
        place: In::Raw,
    },
    Site {
        file: "src/codec/entropy.rs",
        role: "backend name parse arm",
        token: "\"rans4\" =>",
        place: In::Raw,
    },
    // Container-version dispatch in the directory reader/writer.
    Site {
        file: "src/codec/header.rs",
        role: "container version handling",
        token: "BATCH_MIN_VERSION",
        place: In::Code,
    },
    Site {
        file: "src/codec/header.rs",
        role: "container version handling",
        token: "BATCH_VERSION_PLAIN",
        place: In::Code,
    },
    Site {
        file: "src/codec/header.rs",
        role: "container version handling",
        token: "BATCH_VERSION",
        place: In::Code,
    },
    Site {
        file: "src/codec/header.rs",
        role: "container version handling",
        token: "BATCH_VERSION_TEMPORAL",
        place: In::Code,
    },
    // Format sniffing in the public API.
    Site {
        file: "src/codec/api.rs",
        role: "format sniff (backend id)",
        token: "EntropyKind::from_id",
        place: In::Code,
    },
    Site {
        file: "src/codec/api.rs",
        role: "format sniff (container vs stream)",
        token: "is_batched",
        place: In::Code,
    },
    // CLI surface: every backend stays selectable and documented.
    Site { file: "src/main.rs", role: "CLI backend surface", token: "cabac", place: In::Raw },
    Site { file: "src/main.rs", role: "CLI backend surface", token: "rans", place: In::Raw },
    Site { file: "src/main.rs", role: "CLI backend surface", token: "rans4", place: In::Raw },
    // Wire frame-kind dispatch and version window.
    Site {
        file: "src/coordinator/net.rs",
        role: "wire frame dispatch arm",
        token: "FRAME_KIND_ITEM =>",
        place: In::Code,
    },
    Site {
        file: "src/coordinator/net.rs",
        role: "wire frame dispatch arm",
        token: "FRAME_KIND_OUTCOME =>",
        place: In::Code,
    },
    Site {
        file: "src/coordinator/net.rs",
        role: "wire frame dispatch arm",
        token: "FRAME_KIND_BUSY =>",
        place: In::Code,
    },
    Site {
        file: "src/coordinator/net.rs",
        role: "wire frame dispatch arm",
        token: "FRAME_KIND_RESET =>",
        place: In::Code,
    },
    Site {
        file: "src/coordinator/net.rs",
        role: "wire version window",
        token: "NET_VERSION",
        place: In::Code,
    },
    Site {
        file: "src/coordinator/net.rs",
        role: "wire version window",
        token: "NET_MIN_VERSION",
        place: In::Code,
    },
];

pub fn check(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut files: Vec<&'static str> = SITES.iter().map(|s| s.file).collect();
    files.dedup();
    for file_rel in files {
        let Some(file) = SourceFile::load(root, file_rel) else {
            findings.push(Finding {
                lint: LINT,
                file: file_rel.to_string(),
                line: 0,
                message: "dispatch-site file is missing; update SITES in \
                          xtask/src/dispatch.rs if it moved"
                    .to_string(),
            });
            continue;
        };
        for site in SITES.iter().filter(|s| s.file == file_rel) {
            let found = file.lines.iter().enumerate().any(|(i, line)| {
                if file.in_tests(i) {
                    return false;
                }
                match site.place {
                    In::Code => has_token(&line.code, site.token, true, true),
                    In::Raw => {
                        !line.raw.trim_start().starts_with("//")
                            && has_token(&line.raw, site.token, true, true)
                    }
                }
            });
            if !found {
                findings.push(Finding {
                    lint: LINT,
                    file: file_rel.to_string(),
                    line: 0,
                    message: format!(
                        "dispatch site lost its handling of `{}` ({}); every \
                         backend id, container version, and frame kind must stay \
                         handled at each site",
                        site.token, site.role
                    ),
                });
            }
        }
    }
    findings
}

//! Shared textual-scanning infrastructure for the analyze lints:
//! comment/string masking, token search with identifier boundaries,
//! `LINT-ALLOW` escape-hatch resolution, and source-tree walking.
//!
//! Masking is a small state machine over the source text that blanks
//! comments and string/char literal *contents* (quotes survive so lines
//! keep their shape) while preserving newlines, so every lint can match
//! tokens in `Line::code` without false positives from prose, and read
//! `Line::raw` when it needs the comment text back (SAFETY comments,
//! LINT-ALLOW markers).

use std::fs;
use std::path::{Path, PathBuf};

/// One lint finding. `line` is 1-based; 0 means file-level.
#[derive(Debug, Clone)]
pub struct Finding {
    pub lint: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "[{}] {}: {}", self.lint, self.file, self.message)
        } else {
            write!(f, "[{}] {}:{}: {}", self.lint, self.file, self.line, self.message)
        }
    }
}

/// One source line: the original text and the masked twin.
pub struct Line {
    pub raw: String,
    pub code: String,
}

/// A parsed source file: masked lines plus the test-region boundary
/// (first `#[cfg(test)]` line; everything from there to EOF is test
/// code, which the wire lints deliberately skip).
pub struct SourceFile {
    pub rel: String,
    pub lines: Vec<Line>,
    pub test_start: usize,
}

impl SourceFile {
    pub fn load(root: &Path, rel: &str) -> Option<SourceFile> {
        let text = fs::read_to_string(root.join(rel)).ok()?;
        Some(SourceFile::parse(rel, &text))
    }

    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let masked = mask(text);
        let lines: Vec<Line> = text
            .lines()
            .zip(masked.lines())
            .map(|(raw, code)| Line { raw: raw.to_string(), code: code.to_string() })
            .collect();
        let test_start =
            lines.iter().position(|l| l.raw.contains("#[cfg(test)]")).unwrap_or(lines.len());
        SourceFile { rel: rel.to_string(), lines, test_start }
    }

    pub fn in_tests(&self, i: usize) -> bool {
        i >= self.test_start
    }
}

/// Blank comments and string/char-literal contents, preserving newlines
/// and the overall line shape. Handles nested block comments, escape
/// sequences, raw strings (`r"…"`, `r#"…"#`), and distinguishes char
/// literals from lifetimes.
pub fn mask(text: &str) -> String {
    enum St {
        Code,
        LineComment,
        Block(u32),
        Str,
        RawStr(u32),
    }
    let b: Vec<char> = text.chars().collect();
    let n = b.len();
    let mut out = vec![' '; n];
    let mut st = St::Code;
    let mut i = 0;
    while i < n {
        let c = b[i];
        if c == '\n' {
            out[i] = '\n';
            if let St::LineComment = st {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && i + 1 < n && b[i + 1] == '/' {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    out[i] = '"';
                    st = St::Str;
                    i += 1;
                } else if c == 'r'
                    && i + 1 < n
                    && (b[i + 1] == '"' || b[i + 1] == '#')
                    && (i == 0 || !is_ident_char(b[i - 1]))
                {
                    let mut hashes = 0;
                    let mut j = i + 1;
                    while j < n && b[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && b[j] == '"' {
                        out[i] = 'r';
                        st = St::RawStr(hashes);
                        i = j + 1;
                    } else {
                        // `r#ident` raw identifier or attribute soup.
                        out[i] = c;
                        i += 1;
                    }
                } else if c == '\'' {
                    if i + 1 < n && b[i + 1] == '\\' {
                        // Escaped char literal: skip to the closing quote.
                        let mut j = i + 2;
                        while j < n && b[j] != '\'' {
                            j += 1;
                        }
                        i = (j + 1).min(n);
                    } else if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\n' {
                        i += 3; // plain char literal 'x'
                    } else {
                        i += 1; // lifetime: drop the quote, keep the ident
                    }
                } else {
                    out[i] = c;
                    i += 1;
                }
            }
            St::LineComment => i += 1,
            St::Block(d) => {
                if c == '/' && i + 1 < n && b[i + 1] == '*' {
                    st = St::Block(d + 1);
                    i += 2;
                } else if c == '*' && i + 1 < n && b[i + 1] == '/' {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    out[i] = '"';
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut k = 0;
                    while j < n && k < h && b[j] == '#' {
                        k += 1;
                        j += 1;
                    }
                    if k == h {
                        st = St::Code;
                        i = j;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    out.into_iter().collect()
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Does `hay` contain `needle`, optionally requiring that no identifier
/// character touches the match on the checked side(s)?
pub fn has_token(hay: &str, needle: &str, boundary_before: bool, boundary_after: bool) -> bool {
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let end = at + needle.len();
        let ok_before = !boundary_before || at == 0 || !is_ident_byte(bytes[at - 1]);
        let ok_after = !boundary_after || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if ok_before && ok_after {
            return true;
        }
        from = at + 1;
    }
    false
}

/// A line that is purely commentary: a `//` line, or the interior of a
/// block comment (masked to nothing while the raw text is not).
pub fn is_comment_line(line: &Line) -> bool {
    let t = line.raw.trim_start();
    if t.starts_with("//") {
        return true;
    }
    line.code.trim().is_empty() && !line.raw.trim().is_empty() && !t.starts_with("#[")
}

/// Resolve the `// LINT-ALLOW(kind): <reason>` escape hatch into a
/// per-line allow mask.
///
/// * Trailing marker — allows its own line.
/// * Marker comment directly above a statement — allows the whole
///   statement, through its terminating `;` or opening `{` (attributes
///   and further comments may sit between).
/// * Marker comment directly above a `fn` signature — allows the whole
///   function body (brace-matched), the form used when every indexing
///   site in a decoder shares one documented length-check invariant.
pub fn allowed_lines(lines: &[Line], kind: &str) -> Vec<bool> {
    let needle = format!("LINT-ALLOW({kind})");
    let n = lines.len();
    let mut allowed = vec![false; n];
    for i in 0..n {
        if !lines[i].raw.contains(&needle) {
            continue;
        }
        allowed[i] = true;
        if !is_comment_line(&lines[i]) {
            continue; // trailing marker: same line only
        }
        // Find the first governed line (skip the rest of the comment).
        let mut j = i + 1;
        while j < n && (is_comment_line(&lines[j]) || lines[j].raw.trim().is_empty()) {
            j += 1;
        }
        if j >= n {
            continue;
        }
        // Skip attributes to see whether a fn signature follows.
        let mut k = j;
        while k < n
            && (lines[k].raw.trim_start().starts_with("#[")
                || is_comment_line(&lines[k])
                || lines[k].raw.trim().is_empty())
        {
            k += 1;
        }
        let end = if k < n && is_fn_signature(&lines[k].code) {
            end_of_block(lines, k)
        } else {
            end_of_statement(lines, j)
        };
        for slot in allowed.iter_mut().take(end + 1).skip(j) {
            *slot = true;
        }
    }
    allowed
}

/// Is this masked line the start of a `fn` item (visibility and
/// qualifiers tolerated)?
pub fn is_fn_signature(code: &str) -> bool {
    let mut s = code.trim_start();
    loop {
        if let Some(rest) = s.strip_prefix("pub") {
            if rest.starts_with('(') {
                match rest.find(')') {
                    Some(p) => {
                        s = rest[p + 1..].trim_start();
                        continue;
                    }
                    None => return false,
                }
            }
            if rest.starts_with(char::is_whitespace) {
                s = rest.trim_start();
                continue;
            }
            return false;
        }
        let mut stripped = false;
        for kw in ["const", "async", "unsafe", "default"] {
            if let Some(rest) = s.strip_prefix(kw) {
                if rest.starts_with(char::is_whitespace) {
                    s = rest.trim_start();
                    stripped = true;
                    break;
                }
            }
        }
        if !stripped {
            break;
        }
    }
    s.starts_with("fn ")
}

/// Line index of the closing brace of the block opened at/after `start`
/// (brace-matched over masked code). A body-less signature (`fn f();`)
/// ends at its semicolon.
pub fn end_of_block(lines: &[Line], start: usize) -> usize {
    let mut depth = 0usize;
    let mut brackets = 0i32;
    let mut seen_brace = false;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    seen_brace = true;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if seen_brace && depth == 0 {
                        return i;
                    }
                }
                '(' | '[' => brackets += 1,
                ')' | ']' => brackets -= 1,
                ';' if !seen_brace && brackets == 0 => return i,
                _ => {}
            }
        }
    }
    lines.len().saturating_sub(1)
}

/// Line index where the statement starting at `start` ends: the first
/// `;` or `{` at zero paren/bracket depth, so a multi-line `let`
/// binding stays covered by the marker comment above it.
pub fn end_of_statement(lines: &[Line], start: usize) -> usize {
    let mut depth = 0i32;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '(' | '[' => depth += 1,
                ')' | ']' => depth -= 1,
                ';' | '{' if depth <= 0 => return i,
                _ => {}
            }
        }
    }
    lines.len().saturating_sub(1)
}

/// All `.rs` files under `dir`, recursively, in sorted order.
pub fn rust_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    collect(dir, &mut out);
    out.sort();
    out
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// `path` relative to `root`, with unix separators.
pub fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_blanks_comments_and_strings() {
        let m = mask("let x = \"unsafe\"; // unsafe here\nlet y = 1;");
        assert!(!m.contains("unsafe"));
        assert!(m.contains("let x = \""));
        assert!(m.contains("let y = 1;"));
    }

    #[test]
    fn mask_handles_raw_strings_and_chars() {
        let m = mask("let s = r#\"panic!(\"#; let c = '\\n'; let l: &'static str = \"x\";");
        assert!(!m.contains("panic!"));
        assert!(m.contains("static"));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("a.unwrap()", ".unwrap()", false, false));
        assert!(!has_token("a.unwrap_or(b)", ".unwrap()", false, false));
        assert!(has_token("bytes[4]", "bytes[", true, false));
        assert!(!has_token("frame_bytes[4]", "bytes[", true, false));
        assert!(has_token("x as u8;", "as u8", true, true));
        assert!(!has_token("class u8x", "as u8", true, true));
    }

    #[test]
    fn fn_signatures() {
        assert!(is_fn_signature("pub(super) fn quantize_avx2(q: &Q) {"));
        assert!(is_fn_signature("pub const fn id() -> u8 {"));
        assert!(is_fn_signature("fn helper() {"));
        assert!(!is_fn_signature("let f = |x| x;"));
        assert!(!is_fn_signature("pub struct Foo {"));
    }

    #[test]
    fn allow_marker_covers_a_whole_fn() {
        let src = "\
// LINT-ALLOW(index): lengths checked by caller.
#[inline]
fn u32_le(bytes: &[u8], at: usize) -> u32 {
    bytes[at]
}
fn other(bytes: &[u8]) -> u8 {
    bytes[0]
}
";
        let f = SourceFile::parse("x.rs", src);
        let allowed = allowed_lines(&f.lines, "index");
        assert!(allowed[3], "inside the annotated fn");
        assert!(!allowed[6], "the next fn is not covered");
    }

    #[test]
    fn allow_marker_covers_a_multi_line_statement() {
        let src = "\
// LINT-ALLOW(panic): count bounded by construction.
let count =
    u32::try_from(items.len()).expect(\"too many\");
let other = x.unwrap();
";
        let f = SourceFile::parse("x.rs", src);
        let allowed = allowed_lines(&f.lines, "panic");
        assert!(allowed[1] && allowed[2], "whole statement is covered");
        assert!(!allowed[3], "the following statement is not");
    }
}

//! Lint 1: unsafe audit.
//!
//! Two contracts:
//! * `unsafe` may appear only in the audited module allowlist —
//!   `codec::simd` (SIMD intrinsics behind runtime dispatch) and
//!   `coordinator::net` (libc poll/pipe FFI). New unsafe surface means
//!   widening the allowlist in a reviewed diff, not slipping a block
//!   into an unrelated module.
//! * Every `unsafe` occurrence (block or fn) must have a `// SAFETY:`
//!   comment on the same line or in the contiguous comment/attribute
//!   run directly above, matching clippy's
//!   `undocumented_unsafe_blocks` convention.

use crate::scan::{has_token, is_comment_line, rel_path, rust_files, Finding, SourceFile};
use std::fs;
use std::path::Path;

pub const LINT: &str = "unsafe-audit";

/// Modules audited for unsafe; everything else must be safe code.
pub const ALLOWLIST: &[&str] = &["src/codec/simd.rs", "src/coordinator/net.rs"];

pub fn check(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    for path in rust_files(&root.join("src")) {
        let rel = rel_path(root, &path);
        let Ok(text) = fs::read_to_string(&path) else {
            continue;
        };
        let file = SourceFile::parse(&rel, &text);
        let allowlisted = ALLOWLIST.contains(&rel.as_str());
        for (i, line) in file.lines.iter().enumerate() {
            if !has_token(&line.code, "unsafe", true, true) {
                continue;
            }
            if !allowlisted {
                findings.push(Finding {
                    lint: LINT,
                    file: rel.clone(),
                    line: i + 1,
                    message: format!(
                        "`unsafe` outside the audited allowlist ({}); move the \
                         operation behind a safe API in an allowlisted module \
                         or extend the allowlist in xtask/src/unsafe_audit.rs \
                         with review",
                        ALLOWLIST.join(", ")
                    ),
                });
            } else if !has_safety_comment(&file, i) {
                findings.push(Finding {
                    lint: LINT,
                    file: rel.clone(),
                    line: i + 1,
                    message: "unsafe block without a `// SAFETY:` comment on the \
                              same line or directly above; state the invariant \
                              that makes this sound"
                        .to_string(),
                });
            }
        }
    }
    findings
}

/// A SAFETY comment counts if it is on the unsafe line itself or within
/// the contiguous run of comment/attribute lines immediately above.
fn has_safety_comment(file: &SourceFile, i: usize) -> bool {
    if file.lines[i].raw.to_uppercase().contains("SAFETY") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let line = &file.lines[j];
        let trimmed = line.raw.trim_start();
        if is_comment_line(line) || trimmed.starts_with("#[") {
            if line.raw.to_uppercase().contains("SAFETY") {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

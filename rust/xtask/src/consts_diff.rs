//! Lint 3: cross-artifact invariant diff.
//!
//! Three artifacts encode the same wire/container identity constants:
//! `src/consts.rs` (the Rust source of truth), the mirror block in
//! `tests/golden/gen_golden.py` (the Python golden generator cannot
//! import Rust), and the committed golden fixture bytes themselves.
//! This lint parses the first two textually and diffs every constant,
//! then scans the fixture files' magic/version/backend-id bytes against
//! the parsed values — so a drive-by edit to any one artifact fails the
//! analyze gate until all three agree.

use crate::scan::Finding;
use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

pub const LINT: &str = "consts-diff";

pub const RUST_CONSTS: &str = "src/consts.rs";
pub const PY_GENERATOR: &str = "tests/golden/gen_golden.py";

/// Every constant that must exist, with the same value, in both the
/// Rust consts module and the Python generator's mirror block.
pub const REQUIRED: &[&str] = &[
    "BATCH_MAGIC",
    "BATCH_MIN_VERSION",
    "BATCH_VERSION_PLAIN",
    "BATCH_VERSION",
    "BATCH_VERSION_TEMPORAL",
    "ENTROPY_ID_CABAC",
    "ENTROPY_ID_RANS",
    "ENTROPY_ID_RANS4",
    "NET_MAGIC",
    "NET_VERSION",
    "NET_MIN_VERSION",
    "FRAME_KIND_ITEM",
    "FRAME_KIND_OUTCOME",
    "FRAME_KIND_BUSY",
    "FRAME_KIND_RESET",
];

pub fn check(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();

    let rust = match fs::read_to_string(root.join(RUST_CONSTS)) {
        Ok(text) => parse_rust_consts(&text),
        Err(_) => {
            findings.push(file_finding(RUST_CONSTS, "constants module is missing"));
            return findings;
        }
    };
    let python = match fs::read_to_string(root.join(PY_GENERATOR)) {
        Ok(text) => parse_python_consts(&text),
        Err(_) => {
            findings.push(file_finding(PY_GENERATOR, "golden generator is missing"));
            return findings;
        }
    };

    for name in REQUIRED {
        if !rust.contains_key(*name) {
            findings.push(file_finding(
                RUST_CONSTS,
                &format!("required constant `{name}` is not defined as a plain literal"),
            ));
        }
    }
    for (name, rv) in &rust {
        match python.get(name) {
            None => findings.push(file_finding(
                PY_GENERATOR,
                &format!(
                    "Rust constant `{name}` has no mirror in the generator's \
                     constants block"
                ),
            )),
            Some(pv) if !values_equal(rv, pv) => findings.push(file_finding(
                PY_GENERATOR,
                &format!("constant `{name}` diverged: Rust has `{rv}`, Python has `{pv}`"),
            )),
            Some(_) => {}
        }
    }

    scan_fixture_bytes(root, &rust, &mut findings);
    findings
}

fn file_finding(file: &str, message: &str) -> Finding {
    Finding { lint: LINT, file: file.to_string(), line: 0, message: message.to_string() }
}

/// Parse `pub const NAME: T = VALUE;` lines; the value keeps its source
/// spelling minus a leading deref (`*b"LWFB"` → `b"LWFB"`).
fn parse_rust_consts(text: &str) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix("pub const ") else {
            continue;
        };
        let Some((name, after_name)) = rest.split_once(':') else {
            continue;
        };
        let Some((_, value)) = after_name.split_once('=') else {
            continue;
        };
        let value = value.trim().trim_end_matches(';').trim().trim_start_matches('*');
        out.insert(name.trim().to_string(), value.to_string());
    }
    out
}

/// Parse `NAME = value` lines with const-shaped names (uppercase, first
/// definition wins — the mirror block sits near the top of the file).
fn parse_python_consts(text: &str) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let Some((name, value)) = line.split_once(" = ") else {
            continue;
        };
        let name = name.trim();
        let mut chars = name.chars();
        let const_like = chars.next().is_some_and(|c| c.is_ascii_uppercase())
            && name.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_');
        if !const_like {
            continue;
        }
        let value = match value.find('#') {
            Some(p) => value[..p].trim(),
            None => value.trim(),
        };
        out.entry(name.to_string()).or_insert_with(|| value.to_string());
    }
    out
}

/// Values compare numerically when both sides parse as integers, else
/// as normalized source strings (covers the `b"LWFB"` magics).
fn values_equal(rust: &str, python: &str) -> bool {
    match (parse_int(rust), parse_int(python)) {
        (Some(a), Some(b)) => a == b,
        _ => rust == python,
    }
}

fn parse_int(s: &str) -> Option<u64> {
    let s = s.replace('_', "");
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// `b"LWFB"` → the 4 magic bytes.
fn magic_bytes(value: &str) -> Option<Vec<u8>> {
    let inner = value.strip_prefix("b\"")?.strip_suffix('"')?;
    Some(inner.bytes().collect())
}

fn const_u8(map: &BTreeMap<String, String>, name: &str) -> Option<u8> {
    parse_int(map.get(name)?).and_then(|v| u8::try_from(v).ok())
}

/// Byte-level scan of the committed fixtures: container files must open
/// with the batch magic, a known version, and a known backend id;
/// single-stream files must advertise a known backend id in the header
/// byte's top two bits.
fn scan_fixture_bytes(root: &Path, rust: &BTreeMap<String, String>, findings: &mut Vec<Finding>) {
    let (Some(magic), Some(vmin), Some(vmax)) = (
        rust.get("BATCH_MAGIC").and_then(|v| magic_bytes(v)),
        const_u8(rust, "BATCH_MIN_VERSION"),
        const_u8(rust, "BATCH_VERSION_TEMPORAL"),
    ) else {
        return; // already reported as missing constants
    };
    let ids: Vec<u8> = ["ENTROPY_ID_CABAC", "ENTROPY_ID_RANS", "ENTROPY_ID_RANS4"]
        .iter()
        .filter_map(|n| const_u8(rust, n))
        .collect();

    let dir = root.join("tests/golden");
    let Ok(entries) = fs::read_dir(&dir) else {
        findings.push(file_finding("tests/golden", "golden fixture directory is missing"));
        return;
    };
    let mut paths: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for path in paths {
        let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        let rel = format!("tests/golden/{name}");
        match ext {
            "lwfb" => {
                let Ok(bytes) = fs::read(&path) else {
                    continue;
                };
                if bytes.len() < 6 {
                    findings.push(file_finding(&rel, "container fixture shorter than its prelude"));
                    continue;
                }
                if bytes[..4] != magic[..] {
                    findings.push(file_finding(
                        &rel,
                        "container fixture does not start with BATCH_MAGIC",
                    ));
                }
                if !(vmin..=vmax).contains(&bytes[4]) {
                    findings.push(file_finding(
                        &rel,
                        &format!(
                            "container version byte {} outside \
                             BATCH_MIN_VERSION..=BATCH_VERSION_TEMPORAL ({vmin}..={vmax})",
                            bytes[4]
                        ),
                    ));
                }
                if !ids.contains(&bytes[5]) {
                    findings.push(file_finding(
                        &rel,
                        &format!("container backend-id byte {} is not an assigned id", bytes[5]),
                    ));
                }
            }
            "lwfc" => {
                let Ok(bytes) = fs::read(&path) else {
                    continue;
                };
                let Some(first) = bytes.first() else {
                    findings.push(file_finding(&rel, "empty stream fixture"));
                    continue;
                };
                let id = first >> 6;
                if !ids.contains(&id) {
                    findings.push(file_finding(
                        &rel,
                        &format!("stream header advertises backend id {id}, which is unassigned"),
                    ));
                }
            }
            _ => {}
        }
    }
}

//! Lint 2: panic-freedom on the wire-facing decode paths.
//!
//! A malformed or truncated bitstream must surface as `Err`, never as a
//! panic that takes down the serving daemon. The module-scoped clippy
//! denies catch `unwrap`/`expect`; this lint additionally catches the
//! panic macros and unchecked slice indexing on the buffers that carry
//! untrusted bytes, and enforces that every exception is documented
//! with `// LINT-ALLOW(panic|index): <reason>`.

use crate::scan::{allowed_lines, has_token, Finding, SourceFile};
use std::path::Path;

pub const LINT: &str = "panic-freedom";

/// The modules that parse bytes arriving from outside the process.
pub const WIRE_MODULES: &[&str] = &[
    "src/codec/header.rs",
    "src/codec/entropy.rs",
    "src/codec/cabac.rs",
    "src/codec/bitstream.rs",
    "src/codec/stream.rs",
    "src/coordinator/net.rs",
    "src/coordinator/protocol.rs",
];

/// (token, require identifier boundary before the match)
const PANIC_TOKENS: &[(&str, bool)] = &[
    (".unwrap()", false),
    (".expect(", false),
    ("panic!", true),
    ("unreachable!", true),
    ("todo!", true),
    ("unimplemented!", true),
];

/// Buffer names that hold untrusted wire bytes; `name[` on these is an
/// unchecked index unless the surrounding code documents the bound.
const INDEXED_NAMES: &[&str] = &["bytes", "buf", "payload", "header"];

pub fn check(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    for rel in WIRE_MODULES {
        let Some(file) = SourceFile::load(root, rel) else {
            findings.push(Finding {
                lint: LINT,
                file: (*rel).to_string(),
                line: 0,
                message: "wire module listed in xtask/src/panics.rs is missing; \
                          update WIRE_MODULES if it moved"
                    .to_string(),
            });
            continue;
        };
        let allow_panic = allowed_lines(&file.lines, "panic");
        let allow_index = allowed_lines(&file.lines, "index");
        for (i, line) in file.lines.iter().enumerate() {
            if file.in_tests(i) {
                break;
            }
            if !allow_panic[i] {
                for (token, before) in PANIC_TOKENS {
                    if has_token(&line.code, token, *before, false) {
                        findings.push(Finding {
                            lint: LINT,
                            file: (*rel).to_string(),
                            line: i + 1,
                            message: format!(
                                "`{token}` in a wire-facing decode module; return a \
                                 typed error instead, or document the invariant with \
                                 `// LINT-ALLOW(panic): <reason>`"
                            ),
                        });
                        break;
                    }
                }
            }
            if !allow_index[i] {
                for name in INDEXED_NAMES {
                    let needle = format!("{name}[");
                    if has_token(&line.code, &needle, true, false) {
                        findings.push(Finding {
                            lint: LINT,
                            file: (*rel).to_string(),
                            line: i + 1,
                            message: format!(
                                "unchecked index `{name}[..]` on a wire buffer; use \
                                 `get(..)` with an error path, or document the bound \
                                 with `// LINT-ALLOW(index): <reason>`"
                            ),
                        });
                        break;
                    }
                }
            }
        }
    }
    findings
}

//! `cargo xtask analyze` — run the repo-native static-analysis pass.
//!
//! Exits non-zero if any lint fires; CI runs this as a blocking job.
//! `--root <dir>` points the pass at a different tree (used by the
//! fixture tests to prove each lint actually catches its violation).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd = None;
    let mut root = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                if i + 1 >= args.len() {
                    eprintln!("error: --root needs a path");
                    return ExitCode::FAILURE;
                }
                root = Some(PathBuf::from(&args[i + 1]));
                i += 2;
            }
            flag if flag.starts_with('-') => {
                eprintln!("error: unknown flag `{flag}`");
                return usage();
            }
            sub if cmd.is_none() => {
                cmd = Some(sub.to_string());
                i += 1;
            }
            extra => {
                eprintln!("error: unexpected argument `{extra}`");
                return usage();
            }
        }
    }

    match cmd.as_deref() {
        Some("analyze") => {
            // The xtask package sits at rust/xtask; the analyzed tree
            // root is the rust/ directory above it.
            let default_root =
                Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf);
            let Some(root) = root.or(default_root) else {
                eprintln!("error: cannot locate the rust/ tree; pass --root");
                return ExitCode::FAILURE;
            };
            let findings = xtask::analyze(&root);
            for finding in &findings {
                eprintln!("{finding}");
            }
            if findings.is_empty() {
                println!("analyze: clean ({})", root.display());
                ExitCode::SUCCESS
            } else {
                eprintln!("analyze: {} finding(s) in {}", findings.len(), root.display());
                ExitCode::FAILURE
            }
        }
        _ => usage(),
    }
}

fn usage() -> ExitCode {
    eprintln!("usage: cargo xtask analyze [--root <rust-tree>]");
    ExitCode::FAILURE
}

//! Lint 4: truncating casts on serialization paths.
//!
//! A bare `as u8/u16/u32` silently wraps when the value outgrows the
//! wire field — the bug class that motivated the checked-conversion
//! rework of `codec::header` and `coordinator::protocol`. On those two
//! files every narrowing must go through `u8::try_from(..)`-style
//! checked conversions (or a documented `// LINT-ALLOW(cast): <why>`
//! when the value is already masked to range).

use crate::scan::{allowed_lines, has_token, Finding, SourceFile};
use std::path::Path;

pub const LINT: &str = "truncating-cast";

/// Serialization modules where a silent wrap corrupts the wire format.
pub const FILES: &[&str] = &["src/codec/header.rs", "src/coordinator/protocol.rs"];

const CAST_TOKENS: &[&str] = &["as u8", "as u16", "as u32"];

pub fn check(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    for rel in FILES {
        let Some(file) = SourceFile::load(root, rel) else {
            findings.push(Finding {
                lint: LINT,
                file: (*rel).to_string(),
                line: 0,
                message: "serialization module listed in xtask/src/casts.rs is \
                          missing; update FILES if it moved"
                    .to_string(),
            });
            continue;
        };
        let allow = allowed_lines(&file.lines, "cast");
        for (i, line) in file.lines.iter().enumerate() {
            if file.in_tests(i) {
                break;
            }
            if allow[i] {
                continue;
            }
            for token in CAST_TOKENS {
                if has_token(&line.code, token, true, true) {
                    findings.push(Finding {
                        lint: LINT,
                        file: (*rel).to_string(),
                        line: i + 1,
                        message: format!(
                            "bare `{token}` on a serialization path; use a checked \
                             `try_from` conversion, or document the range with \
                             `// LINT-ALLOW(cast): <reason>`"
                        ),
                    });
                    break;
                }
            }
        }
    }
    findings
}

//! Repo-native static analysis, run as `cargo xtask analyze`.
//!
//! Five lints, each encoding an invariant this codebase actually relies
//! on and that rustc/clippy cannot express:
//!
//! 1. **unsafe audit** ([`unsafe_audit`]) — every `unsafe` carries a
//!    `// SAFETY:` comment, and `unsafe` exists only inside the audited
//!    module allowlist (`codec::simd`, `coordinator::net`).
//! 2. **panic-freedom** ([`panics`]) — no `unwrap`/`expect`/`panic!`-
//!    family macros and no unchecked slice indexing in the wire-facing
//!    decode modules; escape hatch `// LINT-ALLOW(panic|index): <why>`.
//! 3. **cross-artifact invariant diff** ([`consts_diff`]) — the wire and
//!    container constants in `src/consts.rs`, the Python golden
//!    generator's mirror block, and the committed golden fixture bytes
//!    must all agree.
//! 4. **truncating-cast lint** ([`casts`]) — no bare `as u8/u16/u32` on
//!    the serialization paths (`codec::header`, `coordinator::protocol`);
//!    escape hatch `// LINT-ALLOW(cast): <why>`.
//! 5. **exhaustive dispatch** ([`dispatch`]) — every entropy-backend id,
//!    container version, and wire frame kind stays handled at each of
//!    its dispatch sites (encode, decode, sniff, CLI).
//!
//! All lints are textual (see [`scan`]) — no compiler in the loop, so
//! the same pass can diff Rust against Python and fixture bytes, and it
//! runs in milliseconds as a blocking CI job. The lint taxonomy and the
//! `LINT-ALLOW` convention are documented for contributors in
//! `rust/README.md` ("Static analysis").

pub mod casts;
pub mod consts_diff;
pub mod dispatch;
pub mod panics;
pub mod scan;
pub mod unsafe_audit;

pub use scan::Finding;

use std::path::Path;

/// Run every lint against a repo tree rooted at the `rust/` directory.
/// Returns all findings; an empty vector means the tree is clean.
pub fn analyze(root: &Path) -> Vec<Finding> {
    let mut findings = Vec::new();
    findings.extend(unsafe_audit::check(root));
    findings.extend(panics::check(root));
    findings.extend(consts_diff::check(root));
    findings.extend(casts::check(root));
    findings.extend(dispatch::check(root));
    findings
}

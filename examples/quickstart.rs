//! Quickstart: compress and decompress one real split-layer tensor.
//!
//! Loads the ci-resnet edge artifact, runs one batch of validation images
//! through it, fits the paper's asymmetric-Laplace model from the tensor's
//! own statistics, picks the model-optimal clipping range, and pushes the
//! tensor through the full lightweight codec (clip → 2-bit quantize →
//! truncated unary → CABAC), reporting rate and reconstruction error.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use lwfc::codec::UniformQuantizer;
use lwfc::modeling::{fit_leaky, optimal_cmax};
use lwfc::runtime::{Manifest, Runtime};
use lwfc::tensor::Tensor;
use lwfc::CodecBuilder;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;
    let split = manifest.resnet_split(2)?;
    let edge = rt.load(&split.edge)?;
    println!("loaded {} on {}", edge.name, rt.platform());

    // 1. One batch of deterministic validation images -> split tensor.
    let b = manifest.serve_batch;
    let (xs, _labels) = lwfc::data::gen_class_batch(manifest.val_seed, 0, b);
    let features = edge.run1(&[&Tensor::new(&[b, 32, 32, 3], xs)])?;
    let item = &features.data()[..features.len() / b]; // first image's tensor
    println!("split tensor: {:?} ({} elements/item)", features.shape(), item.len());

    // 2. Fit the paper's model from sample moments (Eqs. 2-8).
    let n = item.len() as f64;
    let mean = item.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = item.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    let model = fit_leaky(mean, var).map_err(anyhow::Error::msg)?;
    println!("fitted asymmetric Laplace: λ={:.4} μ={:.4}", model.input.lambda, model.input.mu);

    // 3. Optimal clipping for a 4-level (2-bit) quantizer (Eqs. 9-11).
    let levels = 4;
    let clip = optimal_cmax(&model.pdf, 0.0, levels);
    println!("model-optimal clip range for N={levels}: [0, {:.4}]", clip.c_max);

    // 4. One codec session: encode -> bit-stream -> decode. The session
    //    owns backend + scratch; `expect_elements` is the decode contract
    //    for the non-self-describing single-stream format.
    let q = UniformQuantizer::new(0.0, clip.c_max as f32, levels);
    let mut codec = CodecBuilder::new(q)
        .image_size(32)
        .expect_elements(item.len())
        .build();
    let stream = codec.encode(item);
    println!(
        "encoded {} elements -> {} bytes = {:.3} bits/element (12-byte header included)",
        stream.elements,
        stream.bytes.len(),
        stream.bits_per_element()
    );

    let decoded = codec.decode(&stream.bytes)?;
    let header = decoded.info.header.as_ref().expect("clean decode has a header");
    let mse: f64 = item
        .iter()
        .zip(&decoded.values)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / n;
    println!(
        "decoded with header N={} clip=[{}, {:.4}]; reconstruction MSE = {:.6}",
        header.levels, header.c_min, header.c_max, mse
    );
    println!("analytic e_tot at this range   = {:.6}", clip.e_tot);
    Ok(())
}

//! Table I analog on this testbed: empirical vs model-based vs ACIQ
//! clipping ranges for all three networks (compact version of
//! `lwfc experiment table1`).
//!
//! Run: `make artifacts && cargo run --release --example model_vs_empirical`

use lwfc::experiments::common::{all_tasks, fit_cache, ExpCtx, ValCache};
use lwfc::experiments::fig2::sweep_cmax_grid;
use lwfc::codec::UniformQuantizer;
use lwfc::modeling::{aciq_cmax, estimate_b, optimal_cmax};
use lwfc::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let ctx = ExpCtx::new(manifest, std::path::Path::new("results"), 128)?;
    for (name, task) in all_tasks() {
        let cache = ValCache::build(&ctx.manifest, task, ctx.val_n)?;
        let model = fit_cache(&cache)?;
        let b = estimate_b(&cache.features);
        println!(
            "\n{name}: clean={:.4}  λ={:.4} μ={:.4} laplace-b={b:.4}",
            cache.metric_with(|x| x)?,
            model.input.lambda,
            model.input.mu
        );
        println!("  N | empirical c_max | model c_max | ACIQ c_max");
        let grid = sweep_cmax_grid(cache.max_value());
        for levels in [2usize, 4, 8] {
            let mut emp = (f64::NEG_INFINITY, 0.0f32);
            for &c in &grid {
                let q = UniformQuantizer::new(0.0, c, levels);
                let m = cache.metric_with(|x| q.fake_quant(x))?;
                if m > emp.0 {
                    emp = (m, c);
                }
            }
            println!(
                "  {levels} | {:>15.3} | {:>11.3} | {:>10.3}",
                emp.1,
                optimal_cmax(&model.pdf, 0.0, levels).c_max,
                aciq_cmax(b, levels)
            );
        }
    }
    Ok(())
}

//! Adaptive clipping on a drifting stream (paper §III-E: real-time video
//! adaptation from the most recent few hundred frames).
//!
//! A gain drift (simulating illumination / AGC changes on a camera) is
//! applied to the split-layer tensors. A static encoder keeps the clip
//! range fitted at stream start; the adaptive controller refits the
//! asymmetric-Laplace model from running moments. Reports accuracy and
//! rate for both, phase by phase.
//!
//! Run: `make artifacts && cargo run --release --example adaptive_stream`

use lwfc::coordinator::{kind_preserving_designer, AdaptiveConfig, OnlineDesignController};
use lwfc::data;
use lwfc::modeling::{fit_leaky, optimal_cmax};
use lwfc::runtime::{Manifest, Runtime};
use lwfc::tensor::Tensor;
use lwfc::{CodecBuilder, QuantSpec};

const LEVELS: usize = 4;

fn main() -> anyhow::Result<()> {
    let m = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;
    let split = m.resnet_split(2)?;
    let edge = rt.load(&split.edge)?;
    let cloud = rt.load(&split.cloud)?;
    let b = m.serve_batch;
    let per_item: usize = split.feature[1..].iter().product();

    // Initial fit from manifest stats (stream start).
    let model0 = fit_leaky(split.stats.mean, split.stats.var).map_err(anyhow::Error::msg)?;
    let c0 = optimal_cmax(&model0.pdf, 0.0, LEVELS).c_max;
    println!("initial model c_max = {c0:.4}");

    let spec0 = QuantSpec::Uniform {
        c_min: 0.0,
        c_max: c0 as f32,
        levels: LEVELS,
    };
    // Two sessions, one static and one re-designed online via
    // `Codec::set_quant`; both decode with a reused buffer.
    let session = |spec: QuantSpec| {
        CodecBuilder::new(spec)
            .image_size(32)
            .expect_elements(per_item)
            .build()
    };
    let mut static_enc = session(spec0.clone());
    let mut adaptive_enc = session(spec0.clone());
    let acfg = AdaptiveConfig {
        levels: LEVELS,
        refit_every: 32,
        ..Default::default()
    };
    let mut controller = OnlineDesignController::new(
        acfg,
        kind_preserving_designer(&spec0, lwfc::codec::DesignKind::Model, &acfg),
        spec0,
    );

    // Drift schedule: three phases of feature gain.
    let phases: [(f32, &str); 3] = [(1.0, "nominal"), (3.0, "gain x3"), (0.5, "gain x0.5")];
    let frames_per_phase = 384usize;

    println!(
        "\n{:<10} {:>9} {:>9} {:>11} {:>11} {:>10}",
        "phase", "acc(stat)", "acc(adap)", "bits(stat)", "bits(adap)", "adap c_max"
    );
    let mut frame = 0u64;
    for (gain, label) in phases {
        let mut correct = [0usize; 2];
        let mut bits = [0.0f64; 2];
        let mut n = 0usize;
        for start in (0..frames_per_phase).step_by(b) {
            let (xs, ys) = data::gen_class_batch(m.val_seed, frame + start as u64, b);
            let feat = edge.run1(&[&Tensor::new(&[b, 32, 32, 3], xs)])?;
            // Apply the drift gain (what a brighter/darker scene does to
            // activation magnitudes).
            let scaled: Vec<f32> = feat.data().iter().map(|&v| v * gain).collect();

            for (which, enc) in [&mut static_enc, &mut adaptive_enc].into_iter().enumerate() {
                let mut recon = vec![0.0f32; b * per_item];
                let mut vals = Vec::new();
                for i in 0..b {
                    let item = &scaled[i * per_item..(i + 1) * per_item];
                    if which == 1 {
                        if let Some(spec) = controller.observe(item) {
                            enc.set_quant(spec);
                        }
                    }
                    let stream = enc.encode(item);
                    bits[which] += stream.bits_per_element();
                    enc.decode_into(&stream.bytes, &mut vals)?;
                    recon[i * per_item..(i + 1) * per_item].copy_from_slice(&vals);
                }
                // Undo the gain before the cloud half (receiver-side AGC),
                // so accuracy isolates codec distortion.
                for v in recon.iter_mut() {
                    *v /= gain;
                }
                let logits = cloud.run1(&[&Tensor::new(&split.feature, recon)])?;
                for i in 0..b {
                    let row = &logits.data()[i * 10..(i + 1) * 10];
                    let best = row
                        .iter()
                        .enumerate()
                        .max_by(|a, z| a.1.partial_cmp(z.1).unwrap())
                        .unwrap()
                        .0;
                    if best == ys[i] {
                        correct[which] += 1;
                    }
                }
            }
            n += b;
        }
        frame += frames_per_phase as u64;
        println!(
            "{:<10} {:>9.4} {:>9.4} {:>11.3} {:>11.3} {:>10.3}",
            label,
            correct[0] as f64 / n as f64,
            correct[1] as f64 / n as f64,
            bits[0] / n as f64,
            bits[1] / n as f64,
            controller.c_max()
        );
    }
    println!(
        "\nadaptive controller refits: {} (window mean {:.4}, var {:.4})",
        controller.refits,
        controller.mean(),
        controller.variance()
    );
    Ok(())
}

//! END-TO-END serving driver (DESIGN.md §5 "e2e"): the full collaborative-
//! intelligence stack on a real workload.
//!
//! Simulated edge devices regenerate validation images, run the AOT edge
//! network via PJRT, compress the split tensor with the lightweight codec
//! (model-optimal clipping), ship bit-streams through a bounded "network"
//! queue, and a cloud worker decodes + finishes inference. Reports task
//! quality, real compressed rate, latency percentiles and throughput for
//! both the classification and the detection network, plus an uncompressed
//! float32 baseline for the rate comparison.
//!
//! Run: `make artifacts && cargo run --release --example edge_cloud_serving`

use lwfc::codec::EntropyKind;
use lwfc::coordinator::{
    serve, CloudConfig, EdgeConfig, QuantSpec, ServeConfig, TaskKind, TransportKind,
};
use lwfc::experiments::common::family_of;
use lwfc::modeling::{fit, optimal_cmax};
use lwfc::runtime::Manifest;

fn run_task(m: &Manifest, task: TaskKind, levels: usize, requests: usize) -> anyhow::Result<()> {
    let stats = match task {
        TaskKind::ClassifyResnet { split } => m.resnet_split(split)?.stats,
        TaskKind::ClassifyAlex => m.alex.stats,
        TaskKind::Detect => m.detect.stats,
    };
    let (act, kappa) = family_of(task);
    let model = fit(stats.mean, stats.var, kappa, act).map_err(anyhow::Error::msg)?;
    let c_max = optimal_cmax(&model.pdf, 0.0, levels).c_max;

    println!("\n=== {task}: N={levels}, model c_max={c_max:.4} ===");
    let cfg = ServeConfig {
        edge: EdgeConfig {
            task,
            quant: QuantSpec::Uniform {
                c_min: 0.0,
                c_max: c_max as f32,
                levels,
            },
            entropy: EntropyKind::Cabac,
            val_seed: m.val_seed,
            batch: m.serve_batch,
            design: lwfc::codec::DesignKind::Static,
            granularity: lwfc::codec::ClipGranularity::Stream,
            adaptive: None,
            threads: 2,
        },
        cloud: CloudConfig {
            task,
            val_seed: m.val_seed,
            batch: m.serve_batch,
            obj_threshold: 0.3,
            threads: 2,
        },
        edge_workers: 2,
        requests,
        queue_capacity: 64,
        first_index: 0,
        transport: TransportKind::Loopback,
    };
    let report = serve(m, cfg)?;
    println!("{}", report.summary());
    println!(
        "compression vs raw f32: {:.0}x (32 bits -> {:.3} bits/element)",
        32.0 / report.bits_per_element,
        report.bits_per_element
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let m = Manifest::load(&Manifest::default_dir())?;
    println!(
        "artifacts: serve_batch={} resnet_top1(build)={:.4}",
        m.serve_batch, m.resnet_top1
    );
    run_task(&m, TaskKind::ClassifyResnet { split: 2 }, 4, 512)?;
    run_task(&m, TaskKind::ClassifyResnet { split: 2 }, 2, 512)?;
    run_task(&m, TaskKind::Detect, 4, 256)?;
    Ok(())
}
